//! Ablations beyond the paper's figures — the design-choice experiments
//! DESIGN.md calls out:
//!
//! * `rounding`: deterministic RNE vs stochastic rounding for the PS(μ)
//!   accumulator (§2.2.1: c_g = k vs ≈ √k) at the dot-product level.
//! * `recompute_algo`: FP32 recomputation vs Kahan-compensated
//!   recomputation (the "more accurate algorithm" refinement of §2.2.1),
//!   measured on the composition error of softmax(A·x).
//! * `plan_sites`: whole-model LAMP per composition site — for each
//!   non-attention site of the [`PrecisionPlan`](crate::model::plan),
//!   uniform low precision vs per-site look-ahead repair, measured as the
//!   max logit deviation from the FP32 reference.
//! * `weight_storage`: storage format × recomputation rate — quantized
//!   parameter storage ([`crate::linalg::WeightTensor`]: bf16 / PS(μ))
//!   crossed with uniform-PS vs whole-model-LAMP compute at ≤5% overall
//!   recompute rate, against the f32-storage FP32 reference.
//! * `kv_storage`: paged KV-cache storage format × LAMP KV repair rate —
//!   quantized cached K/V rows ([`crate::model::kvstore`]: bf16 / PS(μ))
//!   with look-ahead row pinning at a ≤5% f32 budget vs uniform quantized
//!   KV, against the f32-KV decode oracle.
//! * `speculative`: the self-speculative draft-plan aggressiveness ladder
//!   (τ loosening, then μ coarsening, at fixed look-ahead k) vs measured
//!   acceptance and end-to-end speedup over the non-speculative
//!   target-plan decode — every rung's stream stays bit-identical to solo
//!   by construction.

use crate::benchkit::{fnum, Table};
use crate::error::Result;
use crate::lamp::softmax::{select_strict, softmax, SoftmaxRule};
use crate::linalg::{Matrix, WeightFormat};
use crate::metrics::Accumulator;
use crate::model::{
    forward, generate_with_stats, Decode, DecodeSession, KvBlockPool, KvCacheOptions,
    LampStats, ModelConfig, PrecisionPlan, SitePrecision, SpecConfig, Weights,
};
use crate::softfloat::dot::{dot_f32, dot_f64, dot_kahan, dot_ps, dot_ps_stochastic};
use crate::util::Rng;

/// RNE vs stochastic accumulation error as k grows (§2.2.1: c_g = k
/// worst-case vs ≈ √k with high probability).
///
/// Two regimes:
/// * random-sign products — RNE errors are already ~zero-mean, the two
///   modes are comparable;
/// * small positive increments into a growing accumulator — the classic
///   *stagnation* regime: once increments drop below half an ulp RNE
///   absorbs them entirely (linear-in-k bias), while stochastic rounding
///   stays unbiased. This is where the √k advantage is dramatic.
pub fn rounding_modes() -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    let mut rng = Rng::new(11);
    for (title, positive) in [
        ("ablation — rounding mode, random-sign products (PS(4))", false),
        ("ablation — rounding mode, positive increments / stagnation (PS(4))", true),
    ] {
        let mut t = Table::new(title, &["k", "RNE |err|", "stochastic |err|", "RNE/stochastic"]);
        for k in [16usize, 64, 256, 1024, 4096] {
            let mut acc_rne = Accumulator::new();
            let mut acc_sto = Accumulator::new();
            for _ in 0..64 {
                let (a, b): (Vec<f32>, Vec<f32>) = if positive {
                    (
                        vec![1.0; k],
                        (0..k).map(|_| 0.005 + 0.01 * rng.f32()).collect(),
                    )
                } else {
                    (
                        (0..k).map(|_| rng.f32() * 2.0 - 1.0).collect(),
                        (0..k).map(|_| rng.f32() * 2.0 - 1.0).collect(),
                    )
                };
                let exact = dot_f64(&a, &b);
                acc_rne.push((dot_ps(&a, &b, 4) as f64 - exact).abs());
                acc_sto.push((dot_ps_stochastic(&a, &b, 4, &mut rng) as f64 - exact).abs());
            }
            t.row(vec![
                k.to_string(),
                fnum(acc_rne.mean()),
                fnum(acc_sto.mean()),
                format!("{:.2}", acc_rne.mean() / acc_sto.mean().max(1e-300)),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}

/// FP32 vs Kahan recomputation inside the LAMP loop on softmax(A·x).
pub fn recompute_algorithms() -> Result<Vec<Table>> {
    let mut t = Table::new(
        "ablation — recomputation algorithm for selected products (PS(3), tau=0.05)",
        &["k", "L1 err uniform", "L1 err LAMP/fp32", "L1 err LAMP/kahan"],
    );
    let mut rng = Rng::new(13);
    let n = 32;
    for k in [64usize, 512, 4096] {
        let a = Matrix::randn(n, k, 0.3, &mut rng);
        let x: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
        let y_exact: Vec<f32> = (0..n).map(|i| dot_f32(a.row(i), &x)).collect();
        let z_exact = softmax(&y_exact);

        let y_low: Vec<f32> = (0..n).map(|i| dot_ps(a.row(i), &x, 3)).collect();
        let mask = select_strict(&y_low, 0.05);
        let l1 = |z: &[f32]| -> f64 {
            z.iter().zip(&z_exact).map(|(&p, &q)| (p - q).abs() as f64).sum()
        };

        let mut y_f32 = y_low.clone();
        let mut y_kahan = y_low.clone();
        for (j, &m) in mask.iter().enumerate() {
            if m {
                y_f32[j] = dot_f32(a.row(j), &x);
                y_kahan[j] = dot_kahan(a.row(j), &x);
            }
        }
        t.row(vec![
            k.to_string(),
            fnum(l1(&softmax(&y_low))),
            fnum(l1(&softmax(&y_f32))),
            fnum(l1(&softmax(&y_kahan))),
        ]);
    }
    Ok(vec![t])
}

/// Whole-model LAMP per composition site: for the MLP, final-norm, and
/// sampler sites, compare uniform PS(μ) against per-site LAMP repair on
/// the nano model (max logit deviation from the FP32 reference, plus the
/// site's recompute rate).
pub fn plan_sites() -> Result<Vec<Table>> {
    let mut rng = Rng::new(17);
    let weights = Weights::random(&ModelConfig::nano(), &mut rng).unwrap();
    let tokens: Vec<u32> = (0..24).map(|i| (i * 11 + 3) % 128).collect();
    let reference = forward(&weights, &tokens, PrecisionPlan::reference(), 0)?;
    let mut t = Table::new(
        "ablation — whole-model LAMP per composition site (nano, mu=3)",
        &["site", "max |Δlogit| uniform", "max |Δlogit| LAMP", "site recompute%"],
    );
    let base = PrecisionPlan::reference();
    let mu = 3;
    let cases: Vec<(&str, PrecisionPlan, PrecisionPlan)> = vec![
        (
            "mlp (fc->GELU)",
            base.with_mlp(SitePrecision::uniform(mu)),
            base.with_mlp(SitePrecision::lamp(mu, 0.1, SoftmaxRule::Strict)),
        ),
        (
            "norm (residual->LN)",
            base.with_norm(SitePrecision::uniform(mu)),
            base.with_norm(SitePrecision::lamp(mu, 0.1, SoftmaxRule::Strict)),
        ),
        (
            "sampler (logits->softmax)",
            base.with_sampler(SitePrecision::uniform(mu)),
            base.with_sampler(SitePrecision::lamp(mu, 0.0, SoftmaxRule::Strict)),
        ),
    ];
    for (name, uniform_plan, lamp_plan) in cases {
        let uni = forward(&weights, &tokens, uniform_plan, 0)?;
        let rep = forward(&weights, &tokens, lamp_plan, 0)?;
        let e_uni = uni.logits.max_abs_diff(&reference.logits)?;
        let e_rep = rep.logits.max_abs_diff(&reference.logits)?;
        let rate = match name {
            n if n.starts_with("mlp") => rep.stats.mlp.rate(),
            n if n.starts_with("norm") => rep.stats.norm.rate(),
            _ => rep.stats.sampler.rate(),
        };
        t.row(vec![
            name.to_string(),
            fnum(e_uni as f64),
            fnum(e_rep as f64),
            format!("{:.3}", 100.0 * rate),
        ]);
    }
    Ok(vec![t])
}

/// Overall recomputation rate across every composition site.
fn overall_rate(stats: &LampStats) -> f64 {
    let recomputed = stats.recomputed
        + stats.mlp.recomputed
        + stats.norm.recomputed
        + stats.sampler.recomputed;
    let total =
        stats.causal_total + stats.mlp.total + stats.norm.total + stats.sampler.total;
    if total == 0 {
        0.0
    } else {
        recomputed as f64 / total as f64
    }
}

/// Storage format × per-site recomputation — the new scenario opened by
/// mixed-precision weight storage: how much does LAMP compute-repair buy
/// back when the parameters themselves are stored quantized?
///
/// For each storage format (f32 control, bf16, PS(8), PS(4)) the nano
/// model runs three compute regimes against the f32-storage FP32
/// reference: reference compute (isolating the pure storage error — the
/// irreducible floor), uniform PS(3) compute, and whole-model LAMP at
/// PS(3) with the tightest per-site τ rung whose *overall* recompute rate
/// stays ≤ 5% (the paper's low-overhead band). LAMP cannot repair the
/// storage error — the weights are what they are — but it repairs the
/// accumulation error stacked on top, pulling the total back toward the
/// storage floor.
pub fn weight_storage() -> Result<Vec<Table>> {
    let mut rng = Rng::new(19);
    let weights = Weights::random(&ModelConfig::nano(), &mut rng)?;
    let tokens: Vec<u32> = (0..24).map(|i| (i * 13 + 5) % 128).collect();
    let reference = forward(&weights, &tokens, PrecisionPlan::reference(), 0)?;
    let mu = 3;
    let uniform = PrecisionPlan::whole_model(SitePrecision::uniform(mu));
    // τ rungs loosest → tightest: softmax-relative thresholds for the
    // attention/sampler sites, absolute sensitivities for mlp/norm.
    // Tightening τ only adds repairs (monotone), so we walk the ladder and
    // keep the tightest plan whose overall rate fits the 5% budget.
    let softmax_taus = [0.9f32, 0.5, 0.2, 0.1, 0.05, 0.02];
    let abs_taus = [8.0f32, 4.0, 3.0, 2.0, 1.5, 1.0];
    let lamp_rung = |i: usize| -> PrecisionPlan {
        PrecisionPlan::whole_model(SitePrecision::lamp(
            mu,
            softmax_taus[i],
            SoftmaxRule::Strict,
        ))
        .with_mlp(SitePrecision::lamp(mu, abs_taus[i], SoftmaxRule::Strict))
        .with_norm(SitePrecision::lamp(mu, abs_taus[i], SoftmaxRule::Strict))
        .with_sampler(SitePrecision::lamp(mu, softmax_taus[i], SoftmaxRule::Strict))
    };
    // Probe on the f32-storage weights with a small safety margin under
    // the 5% budget: selection counts drift slightly across storage
    // formats (the rules see the quantized values), and the margin keeps
    // every format's realized rate inside the band. If even the loosest
    // rung exceeds the budget, fail loudly instead of reporting a plan
    // that breaks the ≤5% contract the table documents.
    let mut chosen = None;
    for i in 0..softmax_taus.len() {
        let probe = forward(&weights, &tokens, lamp_rung(i), 0)?;
        if overall_rate(&probe.stats) <= 0.04 {
            chosen = Some(lamp_rung(i));
        } else {
            break;
        }
    }
    let chosen = chosen.ok_or_else(|| {
        crate::error::Error::config(
            "weight_storage ablation: no τ rung fits the 5% recompute budget".to_string(),
        )
    })?;

    let mean_err = |m: &Matrix| -> f64 {
        let n = m.data().len().max(1);
        m.data()
            .iter()
            .zip(reference.logits.data())
            .map(|(&a, &b)| (a - b).abs() as f64)
            .sum::<f64>()
            / n as f64
    };
    let mut t = Table::new(
        "ablation — weight storage format x LAMP recomputation (nano, PS(3) compute)",
        &[
            "storage",
            "max |Δ| storage only",
            "max |Δ| uniform PS(3)",
            "max |Δ| LAMP",
            "mean |Δ| uniform",
            "mean |Δ| LAMP",
            "overall recompute%",
        ],
    );
    let formats = [
        WeightFormat::F32,
        WeightFormat::Bf16,
        WeightFormat::PsRounded { mu: 8 },
        WeightFormat::PsRounded { mu: 4 },
    ];
    for fmt in formats {
        let q = weights.quantize_to(fmt)?;
        let storage_only = forward(&q, &tokens, PrecisionPlan::reference(), 0)?;
        let uni = forward(&q, &tokens, uniform, 0)?;
        let rep = forward(&q, &tokens, chosen, 0)?;
        t.row(vec![
            fmt.label(),
            fnum(storage_only.logits.max_abs_diff(&reference.logits)? as f64),
            fnum(uni.logits.max_abs_diff(&reference.logits)? as f64),
            fnum(rep.logits.max_abs_diff(&reference.logits)? as f64),
            fnum(mean_err(&uni.logits)),
            fnum(mean_err(&rep.logits)),
            format!("{:.3}", 100.0 * overall_rate(&rep.stats)),
        ]);
    }
    Ok(vec![t])
}

/// Decode a fixed token stream through a paged KV cache of the given
/// storage format and repair threshold; returns (mean |Δlogit| vs the
/// f32-KV oracle over every step, pinned-row rate).
fn kv_run(
    weights: &Weights,
    tokens: &[u32],
    oracle: &Matrix,
    fmt: WeightFormat,
    tau: f32,
) -> Result<(f64, f64)> {
    let cfg = &weights.config;
    let pool = KvBlockPool::new(
        cfg,
        KvCacheOptions {
            format: fmt,
            repair_tau: tau,
            block_size: 4,
            capacity_blocks: cfg.seq.div_ceil(4),
            sharing: false,
        },
    )?;
    let mut s = DecodeSession::with_pool(weights, PrecisionPlan::reference(), 0, pool);
    let mut err = 0.0f64;
    for (i, &t) in tokens.iter().enumerate() {
        s.decode_step(t)?;
        for (a, b) in s.logits().iter().zip(oracle.row(i)) {
            err += (a - b).abs() as f64;
        }
    }
    let n = (tokens.len() * cfg.vocab) as f64;
    Ok((err / n, s.kv().pinned_rate()))
}

/// KV storage format × LAMP KV repair rate — the scenario opened by the
/// paged mixed-precision KV cache: how much of the quantized-KV decode
/// error does look-ahead row pinning buy back at a bounded f32 budget?
///
/// For each quantized KV format (bf16, PS(3), PS(2)) the nano model
/// decodes a fixed 28-token stream against the f32-KV oracle (which is
/// bit-identical to the historical contiguous cache) under three storage
/// regimes: uniform quantized (`repair_tau = ∞`), LAMP-repaired at the
/// tightest τ whose pinned-row rate fits the ≤5% budget (PR 4's ladder
/// discipline, found by bisection on the monotone rate-vs-τ curve), and
/// a 50%-pinned rung showing the repair trend. Pinned rows are the ones
/// with the largest realized quantization error — under relative
/// rounding these are the largest-magnitude K/V rows, exactly the rows
/// that dominate attention scores — so a few exact rows recover a
/// disproportionate share of the decode error.
pub fn kv_storage() -> Result<Vec<Table>> {
    let mut rng = Rng::new(23);
    let weights = Weights::random(&ModelConfig::nano(), &mut rng)?;
    let cfg = weights.config.clone();
    let tokens: Vec<u32> = (0..28).map(|i| (i * 17 + 3) % 128).collect();
    // Oracle: f32 KV, per-step logits.
    let mut oracle = Matrix::zeros(tokens.len(), cfg.vocab);
    {
        let mut s = DecodeSession::new(&weights, PrecisionPlan::reference(), 0);
        for (i, &t) in tokens.iter().enumerate() {
            s.decode_step(t)?;
            oracle.row_mut(i).copy_from_slice(s.logits());
        }
    }
    let mut t = Table::new(
        "ablation — KV storage format x LAMP KV repair (nano, reference compute)",
        &[
            "kv storage",
            "mean |Δlogit| uniform",
            "mean |Δ| repair<=5%",
            "pin rate%",
            "mean |Δ| repair~50%",
            "pin rate50%",
        ],
    );
    for fmt in [
        WeightFormat::Bf16,
        WeightFormat::PsRounded { mu: 3 },
        WeightFormat::PsRounded { mu: 2 },
    ] {
        let (uni, _) = kv_run(&weights, &tokens, &oracle, fmt, f32::INFINITY)?;
        // Tightest τ whose pinned rate fits `target`: bisection on the
        // monotone (nonincreasing) rate-vs-τ step function.
        let budget = |target: f64| -> Result<(f64, f64)> {
            let mut hi = 1.0f32;
            loop {
                let (_, r) = kv_run(&weights, &tokens, &oracle, fmt, hi)?;
                if r == 0.0 {
                    break;
                }
                hi *= 4.0;
            }
            let mut lo = 0.0f32;
            for _ in 0..40 {
                let mid = 0.5 * (lo + hi);
                let (_, r) = kv_run(&weights, &tokens, &oracle, fmt, mid)?;
                if r <= target {
                    hi = mid;
                } else {
                    lo = mid;
                }
            }
            let (e, r) = kv_run(&weights, &tokens, &oracle, fmt, hi)?;
            Ok((e, r))
        };
        let (rep5, rate5) = budget(0.05)?;
        let (rep50, rate50) = budget(0.50)?;
        t.row(vec![
            fmt.label(),
            fnum(uni),
            fnum(rep5),
            format!("{:.3}", 100.0 * rate5),
            fnum(rep50),
            format!("{:.3}", 100.0 * rate50),
        ]);
    }
    Ok(vec![t])
}

/// Self-speculative decoding: draft-plan aggressiveness vs acceptance and
/// end-to-end speedup. The ladder coarsens in two regimes — first τ
/// loosens at fixed μ (fewer exact repairs in the draft), then μ drops
/// with no repair at all — while the target plan, look-ahead depth k, and
/// the emitted stream stay fixed: every rung decodes the bit-identical
/// token sequence, so the table isolates the *cost* axis (acceptance vs
/// draft cheapness) of the speculation trade.
///
/// Wall-clock speedups here are single-shot and host-dependent —
/// `benches/speculative.rs` owns the real measurement; this table ties
/// the ladder shape to the acceptance accounting.
pub fn speculative() -> Result<Vec<Table>> {
    use std::time::Instant;
    let mut rng = Rng::new(31);
    let weights = Weights::random(&ModelConfig::nano(), &mut rng)?;
    let prompt: Vec<u32> = (0..8u32).map(|i| (i * 11 + 3) % 128).collect();
    let new_tokens = 24usize;
    let seed = 5u64;
    let k = 4usize;
    let target = PrecisionPlan::whole_model(SitePrecision::lamp(3, 0.02, SoftmaxRule::Strict));
    target.validate()?;
    let t0 = Instant::now();
    let (solo_tokens, _) =
        generate_with_stats(&weights, &prompt, new_tokens, target, Decode::Greedy, seed)?;
    let solo_s = t0.elapsed().as_secs_f64();

    let ladder: [(&str, SitePrecision); 4] = [
        ("lamp(3, 0.05)", SitePrecision::lamp(3, 0.05, SoftmaxRule::Strict)),
        ("lamp(3, 0.5)", SitePrecision::lamp(3, 0.5, SoftmaxRule::Strict)),
        ("uniform(3)", SitePrecision::uniform(3)),
        ("uniform(2)", SitePrecision::uniform(2)),
    ];
    let mut t = Table::new(
        "ablation — speculative draft ladder (nano, target lamp(3, 0.02, strict), k=4)",
        &[
            "draft plan",
            "accept%",
            "tok/round",
            "rounds",
            "draft steps",
            "verify chunks",
            "speedup",
            "bit-exact",
        ],
    );
    for (label, draft) in ladder {
        let plan = target.with_spec(Some(SpecConfig::whole_model(draft, k)));
        plan.validate()?;
        let t1 = Instant::now();
        let (tokens, stats) =
            generate_with_stats(&weights, &prompt, new_tokens, plan, Decode::Greedy, seed)?;
        let spec_s = t1.elapsed().as_secs_f64();
        t.row(vec![
            label.to_string(),
            format!("{:.1}", 100.0 * stats.spec.acceptance_rate()),
            format!("{:.2}", stats.spec.mean_accept_len()),
            stats.spec.rounds.to_string(),
            stats.spec.draft_steps.to_string(),
            stats.spec.verify_chunks.to_string(),
            format!("{:.2}x", solo_s / spec_s.max(1e-12)),
            (tokens == solo_tokens).to_string(),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speculative_ablation_is_bit_exact_with_live_accounting() {
        let tables = speculative().unwrap();
        let rows = &tables[0].rows;
        assert_eq!(rows.len(), 4);
        for row in rows {
            assert_eq!(row[7], "true", "{}: spec stream diverged from solo", row[0]);
            let accept: f64 = row[1].parse().unwrap();
            assert!((0.0..=100.0).contains(&accept), "{}: accept%={accept}", row[0]);
            let rounds: u64 = row[3].parse().unwrap();
            assert!(rounds > 0, "{}: no speculative rounds ran", row[0]);
        }
    }

    #[test]
    fn kv_storage_ablation_repair_beats_uniform_within_budget() {
        let tables = kv_storage().unwrap();
        let rows = &tables[0].rows;
        assert_eq!(rows.len(), 3);
        for row in rows {
            let uni: f64 = row[1].parse().unwrap();
            let rate5: f64 = row[3].parse().unwrap();
            let rep50: f64 = row[4].parse().unwrap();
            assert!(uni > 0.0, "{}: uniform quantized KV must perturb logits", row[0]);
            assert!(
                rate5 > 0.0 && rate5 <= 5.0,
                "{}: pinned rate {rate5}% outside the (0, 5%] budget",
                row[0]
            );
            assert!(
                rep50 < uni,
                "{}: pinning half the rows must recover error (rep50={rep50} uni={uni})",
                row[0]
            );
        }
        // The coarse PS formats carry the headline: LAMP-repaired
        // quantized KV beats uniform quantized KV within the ≤5% budget
        // (the pinned rows are the dominant-error rows).
        for name in ["ps3", "ps2"] {
            let row = rows.iter().find(|r| r[0] == name).unwrap();
            let uni: f64 = row[1].parse().unwrap();
            let rep5: f64 = row[2].parse().unwrap();
            assert!(
                rep5 < uni,
                "{name}: <=5% repair must beat uniform ({rep5} vs {uni})"
            );
        }
    }

    #[test]
    fn weight_storage_ablation_lamp_repairs_within_budget() {
        let tables = weight_storage().unwrap();
        let rows = &tables[0].rows;
        assert_eq!(rows.len(), 4);
        // f32 control: no storage error.
        assert_eq!(rows[0][0], "f32");
        assert_eq!(rows[0][1].parse::<f64>().unwrap(), 0.0);
        for row in rows {
            let uni_mean: f64 = row[4].parse().unwrap();
            let lamp_mean: f64 = row[5].parse().unwrap();
            let rate: f64 = row[6].parse().unwrap();
            // The acceptance criterion: LAMP recomputation reduces the
            // quantized-storage forward error at ≤ 5% recompute rate
            // (mean |Δlogit| — the aggregate the repair provably targets;
            // the max column is reported but can sit on an unrepaired
            // product).
            assert!(
                lamp_mean < uni_mean,
                "{}: lamp={lamp_mean} uniform={uni_mean}",
                row[0]
            );
            assert!(rate > 0.0 && rate <= 5.0, "{}: rate={rate}%", row[0]);
        }
    }

    #[test]
    fn plan_sites_ablation_runs_and_repair_helps() {
        let tables = plan_sites().unwrap();
        assert_eq!(tables[0].rows.len(), 3);
        for row in &tables[0].rows {
            let uni: f64 = row[1].parse().unwrap();
            let rep: f64 = row[2].parse().unwrap();
            assert!(
                rep <= uni,
                "per-site LAMP worse than uniform at {}: {rep} vs {uni}",
                row[0]
            );
        }
    }

    #[test]
    fn rounding_ablation_runs_and_shows_sqrt_k_gap() {
        let tables = rounding_modes().unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[1].rows.len(), 5);
        // In the stagnation regime at k=4096 stochastic must be far better
        // than RNE — the k vs √k scaling of §2.2.1.
        let last = tables[1].rows.last().unwrap();
        let ratio: f64 = last[3].parse().unwrap();
        assert!(ratio > 3.0, "expected stochastic advantage at large k, got {ratio}");
        // Random-sign regime: comparable within an order of magnitude.
        let rnd: f64 = tables[0].rows.last().unwrap()[3].parse().unwrap();
        assert!(rnd > 0.1 && rnd < 10.0, "random-sign ratio out of band: {rnd}");
    }

    #[test]
    fn recompute_ablation_runs_and_lamp_helps() {
        let tables = recompute_algorithms().unwrap();
        for row in &tables[0].rows {
            let uni: f64 = row[1].parse().unwrap();
            let lamp: f64 = row[2].parse().unwrap();
            assert!(lamp <= uni, "LAMP worse than uniform? {row:?}");
        }
    }
}
