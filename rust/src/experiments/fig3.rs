//! Figure 3: Pareto boundaries of "strict" LAMP (eq. 8) vs relaxed
//! relative-threshold LAMP (eq. 9), μ=4, xl-sim, web panel. The strict
//! rule is the theoretical optimum; the relaxed boundary should sit only
//! marginally above it (§4.4).

use super::common::{load_weights, tau_grid, EvalOptions, EvalPanel};
use crate::benchkit::{fnum, Table};
use crate::coordinator::{PrecisionPolicy, Rule};
use crate::data::Domain;
use crate::error::Result;
use crate::metrics::{pareto_front, ParetoPoint};

pub const MU: u32 = 4;

/// Sweep one rule into its (rate, KL) and (rate, flip) Pareto points.
pub fn sweep_rule(
    panel: &EvalPanel,
    mu: u32,
    rule: Rule,
    quick: bool,
) -> Result<(Vec<ParetoPoint>, Vec<ParetoPoint>)> {
    let mut kl_pts = Vec::new();
    let mut flip_pts = Vec::new();
    for tau in tau_grid(rule, quick) {
        let r = panel.evaluate(&PrecisionPolicy::lamp(mu, tau, rule), 0)?;
        kl_pts.push(r.pareto_kl(tau as f64));
        flip_pts.push(r.pareto_flip(tau as f64));
    }
    Ok((kl_pts, flip_pts))
}

pub fn run(opts: &EvalOptions) -> Result<Vec<Table>> {
    let weights = load_weights("xl", opts)?;
    let panel = EvalPanel::build(weights, Domain::Web, opts)?;
    let mut tables = Vec::new();
    for (metric, pick) in [("KL", 0usize), ("flip", 1usize)] {
        let mut t = Table::new(
            &format!("Fig 3 — Pareto ({metric} vs recompute%), mu=4: strict vs relaxed"),
            &["rule", "tau", "recompute%", metric],
        );
        for rule in [Rule::Strict, Rule::Relaxed] {
            let (kl_pts, flip_pts) = sweep_rule(&panel, MU, rule, opts.quick)?;
            let pts = if pick == 0 { kl_pts } else { flip_pts };
            for p in pareto_front(&pts) {
                t.row(vec![
                    rule.name().into(),
                    format!("{:.3}", p.tau),
                    format!("{:.3}", 100.0 * p.rate),
                    fnum(p.metric),
                ]);
            }
        }
        tables.push(t);
    }
    Ok(tables)
}
