//! Figure 5 (App. C.2): Pareto boundaries for the xl-sim vs small-sim
//! models, μ=4, web panel. Expected shape: the larger model has the lower
//! boundary (more concentrated softmax ⇒ fewer sensitive products).

use super::common::{load_weights, EvalOptions, EvalPanel};
use super::fig3::sweep_rule;
use crate::benchkit::{fnum, Table};
use crate::coordinator::Rule;
use crate::data::Domain;
use crate::error::Result;
use crate::metrics::pareto_front;

pub fn run(opts: &EvalOptions) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 5 — strict LAMP Pareto (mu=4): xl-sim vs small-sim on web",
        &["model", "tau", "recompute%", "KL", "flip%"],
    );
    for name in ["xl", "small"] {
        let weights = load_weights(name, opts)?;
        let panel = EvalPanel::build(weights, Domain::Web, opts)?;
        let (kl_pts, flip_pts) = sweep_rule(&panel, 4, Rule::Strict, opts.quick)?;
        for p in pareto_front(&kl_pts) {
            let f = flip_pts
                .iter()
                .find(|q| q.tau == p.tau)
                .map(|q| q.metric)
                .unwrap_or(f64::NAN);
            t.row(vec![
                name.into(),
                format!("{:.3}", p.tau),
                format!("{:.3}", 100.0 * p.rate),
                fnum(p.metric),
                format!("{:.3}", 100.0 * f),
            ]);
        }
    }
    Ok(vec![t])
}
