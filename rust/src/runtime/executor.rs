//! Compiled-model executor: owns a PJRT CPU client, a compiled executable
//! and the device-resident weights, and runs batched LAMP forward passes.

use super::artifact::ArtifactStore;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::model::{ModelConfig, Weights};

/// A batched inference request against a compiled model artifact.
#[derive(Debug, Clone)]
pub struct ModelRequest {
    /// Token ids, `batch` rows of `seq` tokens (must match the artifact's
    /// baked shape exactly; the coordinator pads).
    pub tokens: Vec<Vec<u32>>,
    /// Mantissa bits for KQ accumulation (1..=23).
    pub mu: u32,
    /// LAMP threshold (f32::INFINITY = uniform low precision).
    pub tau: f32,
    /// Seed for the Random rule.
    pub seed: i32,
    /// Selection rule code (0 strict, 1 relaxed, 2 relaxed-LN, 3 random) —
    /// see `coordinator::policy`.
    pub mode: i32,
}

/// Result of one batched forward.
#[derive(Debug, Clone)]
pub struct ModelResponse {
    /// Per-sequence logits [S, V].
    pub logits: Vec<Matrix>,
    /// KQ inner products recomputed in FP32 (whole batch).
    pub recomputed: u64,
    /// Causal KQ products in the batch.
    pub causal_total: u64,
}

/// A compiled model bound to its weights.
pub struct ModelExecutor {
    config: ModelConfig,
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    /// Weight buffers in artifact order, transferred to the device once at
    /// load time (§Perf: avoids re-uploading the full parameter set on
    /// every batched call).
    weight_buffers: Vec<xla::PjRtBuffer>,
}

impl ModelExecutor {
    /// Compile `model_<config>.hlo.txt` and stage the trained weights.
    pub fn load(store: &ArtifactStore, config_name: &str) -> Result<Self> {
        let config = store.model_config(config_name)?;
        let weights = store.weights(config_name)?;
        let client = xla::PjRtClient::cpu()?;
        let path = store.model_hlo(config_name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::config("non-UTF8 artifact path".to_string()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let weight_buffers = Self::stage_weights(&client, &weights)?;
        Ok(ModelExecutor { config, client, exe, weight_buffers })
    }

    /// Build an executor from explicit parts (tests use random weights).
    pub fn from_parts(
        config: ModelConfig,
        hlo_path: &std::path::Path,
        weights: &Weights,
    ) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| Error::config("non-UTF8 artifact path".to_string()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let weight_buffers = Self::stage_weights(&client, &weights)?;
        Ok(ModelExecutor { config, client, exe, weight_buffers })
    }

    fn stage_weights(
        client: &xla::PjRtClient,
        weights: &Weights,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let cfg = &weights.config;
        let mut bufs = Vec::new();
        let shapes = weight_shapes(cfg);
        let flat = weights.artifact_order();
        if flat.len() != shapes.len() {
            return Err(Error::invariant("artifact order length mismatch".to_string()));
        }
        for ((_, data), dims) in flat.iter().zip(shapes) {
            bufs.push(client.buffer_from_host_buffer(data, &dims, None)?);
        }
        Ok(bufs)
    }

    pub fn config(&self) -> &ModelConfig {
        &self.config
    }

    /// Execute one batched forward pass.
    pub fn execute(&self, req: &ModelRequest) -> Result<ModelResponse> {
        let cfg = &self.config;
        if req.tokens.len() != cfg.batch {
            return Err(Error::shape(format!(
                "batch {} != artifact batch {}",
                req.tokens.len(),
                cfg.batch
            )));
        }
        if !(1..=23).contains(&req.mu) {
            return Err(Error::config(format!("mu {} out of 1..=23", req.mu)));
        }
        let mut flat_tokens = Vec::with_capacity(cfg.batch * cfg.seq);
        for row in &req.tokens {
            if row.len() != cfg.seq {
                return Err(Error::shape(format!(
                    "sequence length {} != artifact seq {}",
                    row.len(),
                    cfg.seq
                )));
            }
            for &t in row {
                if t as usize >= cfg.vocab {
                    return Err(Error::shape(format!("token {t} >= vocab {}", cfg.vocab)));
                }
                flat_tokens.push(t as i32);
            }
        }
        let tokens_buf = self.client.buffer_from_host_buffer(
            &flat_tokens,
            &[cfg.batch, cfg.seq],
            None,
        )?;
        let mu_buf = self
            .client
            .buffer_from_host_buffer(&[req.mu as i32], &[], None)?;
        let tau_buf = self.client.buffer_from_host_buffer(&[req.tau], &[], None)?;
        let seed_buf = self.client.buffer_from_host_buffer(&[req.seed], &[], None)?;
        let mode_buf = self.client.buffer_from_host_buffer(&[req.mode], &[], None)?;

        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(5 + self.weight_buffers.len());
        args.push(&tokens_buf);
        args.push(&mu_buf);
        args.push(&tau_buf);
        args.push(&seed_buf);
        args.push(&mode_buf);
        for w in &self.weight_buffers {
            args.push(w);
        }

        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
        let elems = result.to_tuple()?;
        if elems.len() != 3 {
            return Err(Error::runtime(format!(
                "expected 3 outputs, got {}",
                elems.len()
            )));
        }
        let logits_flat = elems[0].to_vec::<f32>()?;
        let recomputed = elems[1].to_vec::<f32>()?[0] as u64;
        let causal_total = elems[2].to_vec::<f32>()?[0] as u64;
        let per_seq = cfg.seq * cfg.vocab;
        if logits_flat.len() != cfg.batch * per_seq {
            return Err(Error::runtime(format!(
                "logits size {} != expected {}",
                logits_flat.len(),
                cfg.batch * per_seq
            )));
        }
        let mut logits = Vec::with_capacity(cfg.batch);
        for b in 0..cfg.batch {
            logits.push(Matrix::from_vec(
                cfg.seq,
                cfg.vocab,
                logits_flat[b * per_seq..(b + 1) * per_seq].to_vec(),
            )?);
        }
        Ok(ModelResponse { logits, recomputed, causal_total })
    }
}

/// The artifact-order tensor shapes for `cfg` (mirrors
/// `python/compile/model.py::weight_order`).
pub fn weight_shapes(cfg: &ModelConfig) -> Vec<Vec<usize>> {
    let d = cfg.d_model;
    let dff = cfg.d_ff();
    let mut out = vec![vec![cfg.vocab, d], vec![cfg.seq, d]];
    for _ in 0..cfg.layers {
        out.push(vec![d]);
        out.push(vec![d]);
        out.push(vec![d, 3 * d]);
        out.push(vec![3 * d]);
        out.push(vec![d, d]);
        out.push(vec![d]);
        out.push(vec![d]);
        out.push(vec![d]);
        out.push(vec![d, dff]);
        out.push(vec![dff]);
        out.push(vec![dff, d]);
        out.push(vec![d]);
    }
    out.push(vec![d]);
    out.push(vec![d]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_shapes_match_artifact_order() {
        let cfg = ModelConfig::nano();
        let mut rng = crate::util::Rng::new(1);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        let order = w.artifact_order();
        let shapes = weight_shapes(&cfg);
        assert_eq!(order.len(), shapes.len());
        for ((name, data), dims) in order.iter().zip(&shapes) {
            let n: usize = dims.iter().product();
            assert_eq!(data.len(), n, "{name}");
        }
    }
}
