//! PJRT runtime: load AOT-lowered HLO text artifacts, compile once on the
//! CPU PJRT client, and execute them from the request path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (see `python/compile/aot.py` and
//! /opt/xla-example/README.md: xla_extension 0.5.1 rejects jax≥0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids).

pub mod artifact;
pub mod executor;

pub use artifact::ArtifactStore;
pub use executor::{ModelExecutor, ModelRequest, ModelResponse};
