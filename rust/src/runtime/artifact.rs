//! Artifact discovery and loading: HLO text modules, `.lamp` weights,
//! `.kv` metadata produced by `make artifacts`.

use crate::config::KvConfig;
use crate::error::{Error, Result};
use crate::model::{ModelConfig, Weights};
use std::path::{Path, PathBuf};

/// Locates and validates the artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    dir: PathBuf,
}

impl ArtifactStore {
    /// Open an artifact directory (does not scan eagerly).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(Error::config(format!(
                "artifact directory {dir:?} does not exist — run `make artifacts`"
            )));
        }
        Ok(ArtifactStore { dir })
    }

    /// Default location relative to the repo root, overridable with
    /// `LAMP_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        std::env::var("LAMP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path to the lowered model HLO for `config`.
    pub fn model_hlo(&self, config: &str) -> PathBuf {
        self.dir.join(format!("model_{config}.hlo.txt"))
    }

    /// Path to a standalone kernel HLO.
    pub fn kernel_hlo(&self, kernel: &str) -> PathBuf {
        self.dir.join(format!("kernel_{kernel}.hlo.txt"))
    }

    /// Load the model hyperparameters recorded at artifact build time.
    pub fn model_config(&self, config: &str) -> Result<ModelConfig> {
        let kv = KvConfig::load(self.dir.join(format!("meta_{config}.kv")))?;
        ModelConfig::from_kv(&kv)
    }

    /// Load the trained weights for `config`.
    pub fn weights(&self, config: &str) -> Result<Weights> {
        let cfg = self.model_config(config)?;
        Weights::load(self.dir.join(format!("weights_{config}.lamp")), &cfg)
    }

    /// Names of model configs with complete artifact sets present.
    pub fn available_models(&self) -> Vec<String> {
        let mut out = Vec::new();
        for name in ["nano", "small", "xl"] {
            if self.model_hlo(name).is_file()
                && self.dir.join(format!("weights_{name}.lamp")).is_file()
                && self.dir.join(format!("meta_{name}.kv")).is_file()
            {
                out.push(name.to_string());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_rejected() {
        assert!(ArtifactStore::open("/nonexistent/lamp-artifacts").is_err());
    }

    #[test]
    fn paths_formed_correctly() {
        let tmp = std::env::temp_dir();
        let store = ArtifactStore::open(&tmp).unwrap();
        assert!(store.model_hlo("xl").ends_with("model_xl.hlo.txt"));
        assert!(store.kernel_hlo("ps_matmul").ends_with("kernel_ps_matmul.hlo.txt"));
    }

    #[test]
    fn empty_dir_has_no_models() {
        let tmp = std::env::temp_dir().join("lamp_empty_artifacts");
        std::fs::create_dir_all(&tmp).unwrap();
        let store = ArtifactStore::open(&tmp).unwrap();
        assert!(store.available_models().is_empty());
    }
}
