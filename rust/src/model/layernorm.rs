//! Layer normalization. The normalization arithmetic itself always runs in
//! FP32 (f64 moments); what whole-model LAMP varies is the *input* to the
//! final norm — under an active [`PrecisionPlan`](super::plan::PrecisionPlan)
//! `norm` site, `model::plan::norm_site_row` stores the residual row in
//! PS(μ) and restores the components the RMS-norm greedy solver (§3.2)
//! selects before this function sees them.
//!
//! The gain/shift parameters stay `Vec<f32>` under every weight-storage
//! format ([`crate::linalg::WeightFormat`]): they are O(d) against the
//! matrices' O(d²) and multiply every normalized activation, so
//! quantizing them buys no measurable bandwidth and costs accuracy.

use crate::linalg::simd;

/// y = g ⊙ (x − mean)/√(var + ε) + b, applied in place over one vector.
///
/// The f64 moments run through the pinned SIMD moment chains
/// ([`simd::row_sum_f64`], [`simd::row_sumsq_dev`] — 4×4 f64 accumulators
/// over 16-wide blocks, PR 9), so the normalization is bitwise independent
/// of the dispatched backend; the finish pass is lanewise
/// (bit-transparent).
pub fn layernorm(x: &mut [f32], g: &[f32], b: &[f32], eps: f32) {
    let _t = crate::obs::timers::scoped(crate::obs::timers::Site::Norm);
    let n = x.len();
    debug_assert_eq!(g.len(), n);
    debug_assert_eq!(b.len(), n);
    if n == 0 {
        return;
    }
    let mean = simd::row_sum_f64(x) / n as f64;
    let var = simd::row_sumsq_dev(x, mean) / n as f64;
    let inv = 1.0 / (var + eps as f64).sqrt();
    if !simd::norm_finish_simd(x, mean, inv, g, b) {
        for i in 0..n {
            x[i] = (((x[i] as f64 - mean) * inv) as f32) * g[i] + b[i];
        }
    }
}

/// Standard ε used by GPT-2.
pub const LN_EPS: f32 = 1e-5;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn normalizes_mean_and_var() {
        let mut rng = Rng::new(1);
        let mut x: Vec<f32> = (0..64).map(|_| rng.normal_f32() * 3.0 + 5.0).collect();
        let g = vec![1.0; 64];
        let b = vec![0.0; 64];
        layernorm(&mut x, &g, &b, LN_EPS);
        let mean: f64 = x.iter().map(|&v| v as f64).sum::<f64>() / 64.0;
        let var: f64 = x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / 64.0;
        assert!(mean.abs() < 1e-5, "mean={mean}");
        assert!((var - 1.0).abs() < 1e-3, "var={var}");
    }

    #[test]
    fn scale_and_shift_applied() {
        let mut x = vec![1.0f32, -1.0];
        let g = vec![2.0; 2];
        let b = vec![10.0; 2];
        layernorm(&mut x, &g, &b, 0.0);
        assert!((x[0] - 12.0).abs() < 1e-5);
        assert!((x[1] - 8.0).abs() < 1e-5);
    }

    #[test]
    fn constant_input_maps_to_bias() {
        let mut x = vec![3.0f32; 8];
        let g = vec![1.5; 8];
        let b = vec![0.25; 8];
        layernorm(&mut x, &g, &b, LN_EPS);
        for &v in &x {
            assert!((v - 0.25).abs() < 1e-4);
        }
    }

    #[test]
    fn empty_ok() {
        let mut x: Vec<f32> = vec![];
        layernorm(&mut x, &[], &[], LN_EPS);
    }
}
