//! Paged mixed-precision KV-cache subsystem.
//!
//! At serving scale the dominant resident state is not the weights but the
//! per-session K/V cache, and a contiguous per-session buffer sized for the
//! full context window caps concurrency at `memory / full-context-bytes`
//! regardless of how short requests actually are. This module replaces that
//! layout with vLLM-style block paging plus LAMP-repaired quantized
//! storage:
//!
//! * [`KvBlockPool`] — a slab allocator handing out fixed-size ref-counted
//!   blocks (one block = `block_size` consecutive positions × all layers ×
//!   K and V rows). Sessions allocate lazily as they grow, so memory scales
//!   with *live tokens*, not with the context window, and the pool's
//!   capacity is the serving-level admission currency.
//! * [`PagedKvCache`] — a session's view: a table of block handles with
//!   **prefix sharing** (blocks published under a token-chain hash; a new
//!   session with a matching `(seed, plan, token-prefix)` adopts them and
//!   skips recomputing the prefix) and **copy-on-write** (a shared block
//!   adopted up to a mid-block boundary is copied into an owned block the
//!   first time the session appends into it).
//! * [`KvStore`] — the block payload, mirroring
//!   [`WeightStore`](crate::linalg::WeightStore): `F32` (bit-identical to
//!   the historical contiguous cache), `Bf16` (half the resident bytes),
//!   or `PsRounded{μ}` (storage-error simulation at μ mantissa bits).
//! * **LAMP KV repair** — the look-ahead move of the paper applied to
//!   cached activations: each appended row is quantized, its realized
//!   componentwise error `max_c |x_c − q(x)_c|` (El arar-style forward
//!   error) is compared against the pool's `repair_tau`, and
//!   high-sensitivity rows are pinned at exact f32 in the block's repair
//!   annex while everything else stays quantized. `repair_tau = 0` pins
//!   every inexact row (bit-identical to f32 KV); `repair_tau = ∞` is
//!   uniform quantized storage.
//! * [`lamp_attention_row_kv`] — the fused dequant-on-read attention row
//!   kernel: per cached block it either reads the f32 slab in place (f32
//!   storage — the bit-exact fast path) or gathers the dequantized run
//!   into scratch, then runs the identical PS(μ) score kernel
//!   ([`score_row_ps`]), LAMP selection, FP32 repair, softmax, and value
//!   aggregation as the contiguous [`lamp_attention_row`] it replaces.
//!
//! ## Bit-exactness argument (DESIGN.md §Paged KV cache)
//!
//! With f32 storage a paged cache holds exactly the bytes the contiguous
//! `Matrix` cache held, just scattered across blocks. Every score is an
//! independent accumulator chain (`score_row_ps` is bit-identical per
//! score to `dot_ps`), so computing a causal row in per-block runs yields
//! the same bits as one contiguous call; selection, FP32 repair, softmax,
//! and the ascending-`j` value aggregation then execute the identical
//! FP32 operations in the identical order. Hence f32-backed paging is
//! **bit-identical** to the pre-paging contiguous cache under every
//! [`PrecisionPlan`] (pinned by `rust/tests/decode_parity.rs` and the
//! decode≡forward suites). Prefix sharing preserves this because cached
//! rows are deterministic functions of `(seed, plan, token-prefix)` —
//! exactly the chain-hash key blocks are published under.
//!
//! ## Block lifecycle
//!
//! `alloc` → *Owned* (exclusively writable by one session) → on fill,
//! `publish` freezes it into a shared `Arc` registered in the pool's
//! prefix index (the pool keeps one cache reference, so published blocks
//! survive their session — a prompt cache) → sessions `release` their
//! handles on retirement/preemption; the buffer returns to the free list
//! when the last reference drops, or when the pool **evicts** an unused
//! cached block to satisfy a new allocation. Exhaustion (no free, no
//! evictable) surfaces as a typed [`Error::Resource`] that the scheduler
//! turns into preempt-then-recompute.

use super::attention::{tile_counters, AttentionPrecision, RowLamp};
use super::plan::PrecisionPlan;
use crate::error::{Error, Result};
use crate::lamp::softmax::{select_softmax, softmax_inplace, SoftmaxRule};
use crate::linalg::tensor::{bf16_to_f32, f32_to_bf16};
use crate::linalg::WeightFormat;
use crate::model::config::ModelConfig;
use crate::softfloat::dot::{dot_f32, score_row_ps};
use crate::softfloat::round::round_to_mantissa;
use crate::util::Rng;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, Weak};

/// Flat quantized row storage for one block side (K or V) — the KV twin of
/// [`crate::linalg::WeightStore`]. Every stored value is an exact f32
/// (bf16 widens exactly; PS(μ) values are pre-rounded f32), so
/// dequantization is error-free: quantization error enters once, at
/// [`KvStore::write_row`], never per read.
#[derive(Debug, Clone, PartialEq)]
pub enum KvStore {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    PsRounded { mu: u32, data: Vec<f32> },
}

impl KvStore {
    /// Zero-filled storage for `len` elements under `fmt`.
    pub fn zeros(fmt: WeightFormat, len: usize) -> KvStore {
        match fmt {
            WeightFormat::F32 => KvStore::F32(vec![0.0; len]),
            WeightFormat::Bf16 => KvStore::Bf16(vec![0; len]),
            WeightFormat::PsRounded { mu } => {
                KvStore::PsRounded { mu, data: vec![0.0; len] }
            }
        }
    }

    /// Storage format of this payload.
    pub fn format(&self) -> WeightFormat {
        match self {
            KvStore::F32(_) => WeightFormat::F32,
            KvStore::Bf16(_) => WeightFormat::Bf16,
            KvStore::PsRounded { mu, .. } => WeightFormat::PsRounded { mu: *mu },
        }
    }

    /// Resident payload bytes.
    pub fn resident_bytes(&self) -> usize {
        let len = match self {
            KvStore::F32(d) => d.len(),
            KvStore::Bf16(d) => d.len(),
            KvStore::PsRounded { data, .. } => data.len(),
        };
        len * self.format().bytes_per_element()
    }

    /// Quantize `row` into `[off, off + row.len())`, returning the
    /// realized maximum componentwise error `max_c |x_c − q(x_c)|` — the
    /// look-ahead signal the repair rule thresholds against.
    fn write_row(&mut self, off: usize, row: &[f32]) -> f32 {
        match self {
            KvStore::F32(d) => {
                d[off..off + row.len()].copy_from_slice(row);
                0.0
            }
            KvStore::Bf16(d) => {
                let mut err = 0.0f32;
                for (slot, &x) in d[off..off + row.len()].iter_mut().zip(row) {
                    let b = f32_to_bf16(x);
                    *slot = b;
                    err = err.max((x - bf16_to_f32(b)).abs());
                }
                err
            }
            KvStore::PsRounded { mu, data } => {
                let mut err = 0.0f32;
                for (slot, &x) in data[off..off + row.len()].iter_mut().zip(row) {
                    let q = round_to_mantissa(x, *mu);
                    *slot = q;
                    err = err.max((x - q).abs());
                }
                err
            }
        }
    }

    /// The f32-backed flat payload (`F32` and `PsRounded`); `None` for bf16.
    #[inline]
    fn flat_f32(&self) -> Option<&[f32]> {
        match self {
            KvStore::F32(d) | KvStore::PsRounded { data: d, .. } => Some(d),
            KvStore::Bf16(_) => None,
        }
    }

    /// Dequantize `[off, off + n)` onto the end of `out`.
    fn extend_dequant(&self, off: usize, n: usize, out: &mut Vec<f32>) {
        match self {
            KvStore::F32(d) | KvStore::PsRounded { data: d, .. } => {
                out.extend_from_slice(&d[off..off + n]);
            }
            KvStore::Bf16(d) => {
                out.extend(d[off..off + n].iter().map(|&b| bf16_to_f32(b)));
            }
        }
    }
}

/// One block's payload: K and V rows for `block_size` consecutive
/// positions across every layer, plus the f32 repair annex holding the
/// rows the LAMP look-ahead rule pinned exact. Row `(layer, slot)` lives
/// at flat offset `(layer · block_size + slot) · d` of each slab.
#[derive(Debug)]
pub struct KvBlockData {
    layers: usize,
    block_size: usize,
    d: usize,
    k: KvStore,
    v: KvStore,
    /// Exact-f32 pinned rows, indexed by `layer · block_size + slot`.
    exact_k: Vec<Option<Box<[f32]>>>,
    exact_v: Vec<Option<Box<[f32]>>>,
}

impl KvBlockData {
    fn new(layers: usize, block_size: usize, d: usize, fmt: WeightFormat) -> Self {
        let rows = layers * block_size;
        KvBlockData {
            layers,
            block_size,
            d,
            k: KvStore::zeros(fmt, rows * d),
            v: KvStore::zeros(fmt, rows * d),
            exact_k: (0..rows).map(|_| None).collect(),
            exact_v: (0..rows).map(|_| None).collect(),
        }
    }

    /// Clear the repair annex for buffer reuse. Slab contents may stay
    /// stale: a session only ever reads rows it (or the published origin)
    /// wrote, so stale slab bytes are unreachable — but a stale annex
    /// entry would *shadow* a freshly written row, so it must go.
    fn reset(&mut self) {
        for e in &mut self.exact_k {
            *e = None;
        }
        for e in &mut self.exact_v {
            *e = None;
        }
    }

    #[inline]
    fn idx(&self, layer: usize, slot: usize) -> usize {
        debug_assert!(layer < self.layers && slot < self.block_size);
        layer * self.block_size + slot
    }

    /// Write one position's K and V rows for `layer`, quantizing into the
    /// slab; rows whose realized quantization error exceeds `tau` are
    /// pinned at exact f32 in the annex (the LAMP KV repair). Returns the
    /// number of rows pinned (0..=2).
    fn write_row(
        &mut self,
        layer: usize,
        slot: usize,
        k_row: &[f32],
        v_row: &[f32],
        tau: f32,
    ) -> usize {
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let idx = self.idx(layer, slot);
        let off = idx * self.d;
        let mut pinned = 0;
        let ek = self.k.write_row(off, k_row);
        self.exact_k[idx] = if ek > tau {
            pinned += 1;
            Some(k_row.to_vec().into_boxed_slice())
        } else {
            None
        };
        let ev = self.v.write_row(off, v_row);
        self.exact_v[idx] = if ev > tau {
            pinned += 1;
            Some(v_row.to_vec().into_boxed_slice())
        } else {
            None
        };
        pinned
    }

    /// Clear the repair annex for every slot `>= slot` (all layers) — the
    /// rollback hygiene primitive. Slab bytes at truncated slots are
    /// unreachable (reads are bounded by the cache length) and the next
    /// append overwrites slab *and* annex unconditionally, so this only
    /// keeps `pinned_rows` accounting honest after a speculative rollback.
    fn clear_annex_from(&mut self, slot: usize) {
        for layer in 0..self.layers {
            let idx0 = layer * self.block_size;
            for s in slot..self.block_size {
                self.exact_k[idx0 + s] = None;
                self.exact_v[idx0 + s] = None;
            }
        }
    }

    /// Copy rows `0..valid_slots` (every layer, K and V, annex included)
    /// from `other` — the copy-on-write primitive. Both blocks belong to
    /// the same pool, so the storage formats match and the copy is
    /// byte-exact.
    fn copy_rows_from(&mut self, other: &KvBlockData, valid_slots: usize) {
        debug_assert_eq!(self.block_size, other.block_size);
        debug_assert_eq!(self.layers, other.layers);
        debug_assert!(valid_slots <= self.block_size);
        let copy = |dst: &mut KvStore, src: &KvStore, off: usize, n: usize| match (dst, src) {
            (KvStore::F32(a), KvStore::F32(b)) => {
                a[off..off + n].copy_from_slice(&b[off..off + n]);
            }
            (KvStore::Bf16(a), KvStore::Bf16(b)) => {
                a[off..off + n].copy_from_slice(&b[off..off + n]);
            }
            (
                KvStore::PsRounded { data: a, .. },
                KvStore::PsRounded { data: b, .. },
            ) => {
                a[off..off + n].copy_from_slice(&b[off..off + n]);
            }
            _ => unreachable!("copy-on-write across storage formats"),
        };
        for layer in 0..self.layers {
            let idx0 = layer * self.block_size;
            copy(&mut self.k, &other.k, idx0 * self.d, valid_slots * self.d);
            copy(&mut self.v, &other.v, idx0 * self.d, valid_slots * self.d);
            for slot in 0..valid_slots {
                self.exact_k[idx0 + slot] = other.exact_k[idx0 + slot].clone();
                self.exact_v[idx0 + slot] = other.exact_v[idx0 + slot].clone();
            }
        }
    }

    /// Dequantized K row `(layer, slot)`: the pinned annex row when the
    /// repair rule kept it exact, the slab slice when f32-backed, else a
    /// dequantized copy in `scratch`.
    pub fn k_row<'a>(&'a self, layer: usize, slot: usize, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        row_window(&self.k, &self.exact_k, self.idx(layer, slot), self.d, 0, self.d, scratch)
    }

    /// Dequantized V row `(layer, slot)` — same contract as [`Self::k_row`].
    pub fn v_row<'a>(&'a self, layer: usize, slot: usize, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        row_window(&self.v, &self.exact_v, self.idx(layer, slot), self.d, 0, self.d, scratch)
    }

    /// The contiguous `[n, d]` K-row run starting at `slot0`, readable in
    /// place: `Some` iff the slab is f32-backed and no row in the range is
    /// pinned (f32 storage never pins, so this is always the f32 fast
    /// path — the bit-exact twin of the contiguous cache's slice).
    fn k_run_slice(&self, layer: usize, slot0: usize, n: usize) -> Option<&[f32]> {
        let idx0 = self.idx(layer, slot0);
        debug_assert!(slot0 + n <= self.block_size);
        if self.exact_k[idx0..idx0 + n].iter().any(|e| e.is_some()) {
            return None;
        }
        self.k.flat_f32().map(|d| &d[idx0 * self.d..(idx0 + n) * self.d])
    }

    /// Gather the dequantized `[n, hd]` column window `[off, off + hd)` of
    /// the K-row run starting at `slot0` into `out` (annex rows exact,
    /// slab rows dequantized). Only the caller's head columns are
    /// converted — the attention kernel is invoked once per head, so a
    /// full-width gather would redo the whole row's dequantization
    /// `heads` times per decoded token.
    fn gather_k_cols(
        &self,
        layer: usize,
        slot0: usize,
        n: usize,
        off: usize,
        hd: usize,
        out: &mut Vec<f32>,
    ) {
        out.clear();
        let idx0 = self.idx(layer, slot0);
        for i in 0..n {
            let idx = idx0 + i;
            match &self.exact_k[idx] {
                Some(x) => out.extend_from_slice(&x[off..off + hd]),
                None => self.k.extend_dequant(idx * self.d + off, hd, out),
            }
        }
    }

    /// The dequantized `[off, off + hd)` window of K row `(layer, slot)`
    /// — the per-head analogue of [`Self::k_row`].
    fn k_cols<'a>(
        &'a self,
        layer: usize,
        slot: usize,
        off: usize,
        hd: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        row_window(&self.k, &self.exact_k, self.idx(layer, slot), self.d, off, hd, scratch)
    }

    /// The dequantized `[off, off + hd)` window of V row `(layer, slot)`.
    fn v_cols<'a>(
        &'a self,
        layer: usize,
        slot: usize,
        off: usize,
        hd: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        row_window(&self.v, &self.exact_v, self.idx(layer, slot), self.d, off, hd, scratch)
    }

    /// Rows pinned at exact f32 in the repair annex (K and V counted
    /// separately).
    pub fn pinned_rows(&self) -> usize {
        self.exact_k.iter().filter(|e| e.is_some()).count()
            + self.exact_v.iter().filter(|e| e.is_some()).count()
    }

    /// Resident bytes: both quantized slabs plus the f32 repair annex.
    pub fn resident_bytes(&self) -> usize {
        self.k.resident_bytes() + self.v.resident_bytes() + self.pinned_rows() * self.d * 4
    }
}

/// Shared row-window accessor behind `k_row`/`v_row`/`k_cols`/`v_cols`:
/// the pinned annex row when the repair rule kept it exact, the slab in
/// place when f32-backed, else a dequantized copy in `scratch`. `idx` is
/// the flat `layer · block_size + slot` row index, `[off, off + n)` the
/// column window.
fn row_window<'a>(
    store: &'a KvStore,
    annex: &'a [Option<Box<[f32]>>],
    idx: usize,
    d: usize,
    off: usize,
    n: usize,
    scratch: &'a mut Vec<f32>,
) -> &'a [f32] {
    if let Some(x) = &annex[idx] {
        return &x[off..off + n];
    }
    let o = idx * d + off;
    match store.flat_f32() {
        Some(flat) => &flat[o..o + n],
        None => {
            scratch.clear();
            store.extend_dequant(o, n, scratch);
            &scratch[..]
        }
    }
}

/// A session's handle on one block: exclusively owned (writable) or
/// frozen and prefix-shared.
#[derive(Debug)]
pub enum PagedBlock {
    Owned(Box<KvBlockData>),
    Shared(Arc<KvBlockData>),
}

impl PagedBlock {
    /// Read access to the payload, whichever side owns it.
    #[inline]
    pub fn data(&self) -> &KvBlockData {
        match self {
            PagedBlock::Owned(b) => b,
            PagedBlock::Shared(a) => a,
        }
    }
}

/// Pool configuration — the serving-level KV knobs (`--kv-fmt`,
/// `--kv-tau`).
#[derive(Debug, Clone)]
pub struct KvCacheOptions {
    /// Block slab storage format.
    pub format: WeightFormat,
    /// LAMP KV repair threshold: an appended row whose realized max
    /// componentwise quantization error exceeds this stays pinned at
    /// exact f32. `0.0` pins every inexact row (bit-identical to f32 KV);
    /// `INFINITY` (default) is uniform quantized storage. Ignored for f32.
    pub repair_tau: f32,
    /// Positions per block.
    pub block_size: usize,
    /// Total blocks the pool may have live at once.
    pub capacity_blocks: usize,
    /// Publish filled blocks for prefix sharing. Private (per-session)
    /// pools disable this so solo decode stays byte-for-byte the
    /// historical path; serving pools enable it.
    pub sharing: bool,
}

impl KvCacheOptions {
    /// Default block size — small enough that short prompts span a block
    /// boundary (sharing granularity), large enough to amortize handles.
    pub const DEFAULT_BLOCK_SIZE: usize = 16;

    /// f32, no repair, no sharing, capacity for exactly one full-context
    /// session — the private pool behind `DecodeSession::new`.
    pub fn private(cfg: &ModelConfig) -> Self {
        let block_size = Self::DEFAULT_BLOCK_SIZE.min(cfg.seq.max(1));
        KvCacheOptions {
            format: WeightFormat::F32,
            repair_tau: f32::INFINITY,
            block_size,
            capacity_blocks: cfg.seq.div_ceil(block_size),
            sharing: false,
        }
    }

    /// Serving pool: `fmt` storage with sharing on, sized for `sessions`
    /// concurrent full-context sessions.
    pub fn serving(cfg: &ModelConfig, fmt: WeightFormat, sessions: usize) -> Self {
        let block_size = Self::DEFAULT_BLOCK_SIZE.min(cfg.seq.max(1));
        KvCacheOptions {
            format: fmt,
            repair_tau: f32::INFINITY,
            block_size,
            capacity_blocks: sessions.max(1) * cfg.seq.div_ceil(block_size),
            sharing: true,
        }
    }

    /// Replace the repair threshold.
    pub fn with_repair_tau(mut self, tau: f32) -> Self {
        self.repair_tau = tau;
        self
    }

    /// Range checks, typed errors (front door like the plan validators).
    pub fn validate(&self) -> Result<()> {
        self.format.validate()?;
        if self.block_size == 0 {
            return Err(Error::config("kv cache: block_size must be >= 1".to_string()));
        }
        if self.capacity_blocks == 0 {
            return Err(Error::config(
                "kv cache: capacity_blocks must be >= 1".to_string(),
            ));
        }
        if self.repair_tau.is_nan() || self.repair_tau < 0.0 {
            return Err(Error::config(format!(
                "kv cache: repair_tau {} must be >= 0 and not NaN",
                self.repair_tau
            )));
        }
        Ok(())
    }
}

/// Pool bookkeeping snapshot (the serving metrics source).
#[derive(Debug, Clone, Default)]
pub struct KvPoolStats {
    pub capacity_blocks: usize,
    /// Live blocks (session-held + prompt-cached).
    pub used_blocks: usize,
    /// Capacity headroom (`capacity - used`).
    pub free_blocks: usize,
    /// Recycled buffers parked on the free list.
    pub free_buffers: usize,
    /// Published blocks retained by the prompt cache.
    pub cached_blocks: usize,
    /// Cached blocks no session references (reclaimable on demand).
    pub evictable_blocks: usize,
    /// Prefix-share adoptions (sessions that adopted >= 1 row) and
    /// attempts (sessions that probed the index).
    pub share_hits: usize,
    pub share_lookups: usize,
    /// Total rows adopted instead of recomputed.
    pub shared_rows: usize,
    pub evictions: usize,
    /// Slab-resident bytes of live blocks (`used · slab bytes/block`; the
    /// per-session repair annex is reported by `PagedKvCache`).
    pub resident_bytes: usize,
    /// Slab format label (`f32` / `bf16` / `ps<mu>`).
    pub format: String,
}

impl KvPoolStats {
    /// Fraction of capacity in use.
    pub fn occupancy(&self) -> f64 {
        if self.capacity_blocks == 0 {
            0.0
        } else {
            self.used_blocks as f64 / self.capacity_blocks as f64
        }
    }

    /// Prefix-share hit rate over adoption attempts.
    pub fn share_rate(&self) -> f64 {
        if self.share_lookups == 0 {
            0.0
        } else {
            self.share_hits as f64 / self.share_lookups as f64
        }
    }
}

struct PoolState {
    /// Recycled block buffers.
    free: Vec<Box<KvBlockData>>,
    /// Live blocks: session-held (owned or shared) plus prompt-cached.
    outstanding: usize,
    /// Prefix index: chain hash (covering `j` leading rows of a published
    /// block) → the block. Weak so dead entries cannot pin memory.
    index: HashMap<u64, Weak<KvBlockData>>,
    /// One strong reference per published block — the prompt cache that
    /// keeps shared prefixes alive across sessions until evicted.
    cache: Vec<Arc<KvBlockData>>,
    share_hits: usize,
    share_lookups: usize,
    shared_rows: usize,
    evictions: usize,
}

/// Slab allocator of fixed-size, ref-counted KV blocks shared by every
/// session of one engine. See the module docs for the lifecycle.
pub struct KvBlockPool {
    layers: usize,
    block_size: usize,
    d: usize,
    format: WeightFormat,
    repair_tau: f32,
    capacity: usize,
    sharing: bool,
    state: Mutex<PoolState>,
}

impl KvBlockPool {
    /// Build a pool for `cfg`-shaped sessions.
    pub fn new(cfg: &ModelConfig, opts: KvCacheOptions) -> Result<Arc<Self>> {
        opts.validate()?;
        cfg.validate()?;
        Ok(Arc::new(KvBlockPool {
            layers: cfg.layers,
            block_size: opts.block_size,
            d: cfg.d_model,
            format: opts.format,
            repair_tau: opts.repair_tau,
            capacity: opts.capacity_blocks,
            sharing: opts.sharing,
            state: Mutex::new(PoolState {
                free: Vec::new(),
                outstanding: 0,
                index: HashMap::new(),
                cache: Vec::new(),
                share_hits: 0,
                share_lookups: 0,
                shared_rows: 0,
                evictions: 0,
            }),
        }))
    }

    /// The private single-session pool behind `DecodeSession::new`:
    /// f32 storage, no sharing, exactly one full context of capacity.
    pub fn private_for(cfg: &ModelConfig) -> Arc<Self> {
        Self::new(cfg, KvCacheOptions::private(cfg))
            .expect("private pool options are valid for a valid config")
    }

    pub fn format(&self) -> WeightFormat {
        self.format
    }

    pub fn repair_tau(&self) -> f32 {
        self.repair_tau
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity
    }

    pub fn sharing(&self) -> bool {
        self.sharing
    }

    /// Blocks needed to hold `positions` cached positions.
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.block_size)
    }

    /// Slab bytes of one block (both K and V sides; annex excluded).
    pub fn slab_bytes_per_block(&self) -> usize {
        2 * self.layers * self.block_size * self.d * self.format.bytes_per_element()
    }

    /// Blocks an admission could still obtain: capacity headroom plus
    /// cached blocks nothing references (evicted on demand by `alloc`).
    pub fn available_blocks(&self) -> usize {
        let st = self.state.lock().expect("kv pool lock");
        let evictable =
            st.cache.iter().filter(|a| Arc::strong_count(a) == 1).count();
        self.capacity - st.outstanding + evictable
    }

    /// Bookkeeping snapshot.
    pub fn stats(&self) -> KvPoolStats {
        let st = self.state.lock().expect("kv pool lock");
        let evictable =
            st.cache.iter().filter(|a| Arc::strong_count(a) == 1).count();
        KvPoolStats {
            capacity_blocks: self.capacity,
            used_blocks: st.outstanding,
            free_blocks: self.capacity - st.outstanding,
            free_buffers: st.free.len(),
            cached_blocks: st.cache.len(),
            evictable_blocks: evictable,
            share_hits: st.share_hits,
            share_lookups: st.share_lookups,
            shared_rows: st.shared_rows,
            evictions: st.evictions,
            resident_bytes: st.outstanding * self.slab_bytes_per_block(),
            format: self.format.label(),
        }
    }

    /// Hand out a fresh (reset) owned block buffer. Eviction order when at
    /// capacity: recycled free buffers, then unreferenced prompt-cache
    /// entries (oldest first); with neither, the typed resource error the
    /// scheduler converts into preemption.
    fn alloc(&self) -> Result<Box<KvBlockData>> {
        let mut st = self.state.lock().expect("kv pool lock");
        if let Some(mut b) = st.free.pop() {
            b.reset();
            st.outstanding += 1;
            return Ok(b);
        }
        if st.outstanding < self.capacity {
            st.outstanding += 1;
            return Ok(Box::new(KvBlockData::new(
                self.layers,
                self.block_size,
                self.d,
                self.format,
            )));
        }
        if let Some(i) = st.cache.iter().position(|a| Arc::strong_count(a) == 1) {
            let arc = st.cache.remove(i);
            st.evictions += 1;
            match Arc::try_unwrap(arc) {
                Ok(mut data) => {
                    // Purge the evicted block's (now dead) index entries.
                    st.index.retain(|_, w| w.upgrade().is_some());
                    data.reset();
                    // Net zero on `outstanding`: the cached block died,
                    // its buffer is reborn as the new allocation.
                    return Ok(Box::new(data));
                }
                Err(_) => unreachable!(
                    "strong_count was 1 under the pool lock; no new clone can race"
                ),
            }
        }
        Err(Error::resource(format!(
            "kv block pool exhausted ({} blocks of {} positions)",
            self.capacity, self.block_size
        )))
    }

    /// Return a session's handle. Owned buffers go straight to the free
    /// list; a shared handle frees its buffer only when it was the last
    /// reference (the prompt cache or other sessions may keep it alive).
    fn release(&self, block: PagedBlock) {
        let mut st = self.state.lock().expect("kv pool lock");
        match block {
            PagedBlock::Owned(b) => {
                st.free.push(b);
                st.outstanding -= 1;
            }
            PagedBlock::Shared(arc) => {
                // Reclaim the buffer only when this was the last
                // reference; otherwise the prompt cache / other sessions
                // keep the block alive and accounted.
                if let Ok(data) = Arc::try_unwrap(arc) {
                    st.free.push(Box::new(data));
                    st.outstanding -= 1;
                }
            }
        }
    }

    /// Freeze a filled owned block into a shared one, registering it in
    /// the prefix index under `hashes[j - 1]` = the chain hash covering
    /// its first `j` rows, and retaining one prompt-cache reference.
    fn publish(&self, data: Box<KvBlockData>, hashes: &[u64]) -> Arc<KvBlockData> {
        debug_assert_eq!(hashes.len(), self.block_size);
        let arc = Arc::new(*data);
        let mut st = self.state.lock().expect("kv pool lock");
        for &h in hashes {
            st.index.insert(h, Arc::downgrade(&arc));
        }
        st.cache.push(arc.clone());
        arc
    }

    /// Look up a published block by chain hash.
    fn lookup(&self, hash: u64) -> Option<Arc<KvBlockData>> {
        let st = self.state.lock().expect("kv pool lock");
        st.index.get(&hash).and_then(|w| w.upgrade())
    }

    fn record_adoption(&self, rows: usize) {
        let mut st = self.state.lock().expect("kv pool lock");
        st.share_lookups += 1;
        if rows > 0 {
            st.share_hits += 1;
            st.shared_rows += rows;
        }
    }

    /// Drop every prompt-cache entry no session references; returns the
    /// number of blocks reclaimed. (`alloc` does this lazily one block at
    /// a time; this is the bulk form used by tests and shutdown paths.)
    pub fn evict_unused(&self) -> usize {
        let mut st = self.state.lock().expect("kv pool lock");
        let mut reclaimed = 0;
        let mut i = 0;
        while i < st.cache.len() {
            if Arc::strong_count(&st.cache[i]) == 1 {
                let arc = st.cache.remove(i);
                match Arc::try_unwrap(arc) {
                    Ok(data) => {
                        st.free.push(Box::new(data));
                        st.outstanding -= 1;
                        st.evictions += 1;
                        reclaimed += 1;
                    }
                    Err(_) => unreachable!("strong_count was 1 under the pool lock"),
                }
            } else {
                i += 1;
            }
        }
        st.index.retain(|_, w| w.upgrade().is_some());
        reclaimed
    }
}

impl std::fmt::Debug for KvBlockPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KvBlockPool({} blocks x {} positions, {})",
            self.capacity,
            self.block_size,
            self.format.label()
        )
    }
}

/// One hash-chain fold step (splitmix64 finalizer over `h ⊕ mix(v)`).
#[inline]
fn fold(h: u64, v: u64) -> u64 {
    let mut z = h ^ v.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

fn rule_tag(rule: SoftmaxRule) -> u64 {
    match rule {
        SoftmaxRule::Strict => 1,
        SoftmaxRule::Relaxed => 2,
        SoftmaxRule::RelaxedLengthNorm { ref_len } => 3 ^ ((ref_len as u64) << 8),
        SoftmaxRule::Random => 4,
        SoftmaxRule::Tile { width } => 5 ^ ((width as u64) << 8),
        SoftmaxRule::TileRandom { width } => 6 ^ ((width as u64) << 8),
    }
}

/// Root of a session's token-chain hash. Cached rows are deterministic
/// functions of `(seed, compute-site plan, token prefix)` — the per-site
/// `Random` streams and every kernel are keyed by position — so two
/// sessions may share blocks iff their roots and token prefixes agree.
/// (Storage *requirements* are engine-level and identical across one
/// pool's sessions, so they are not folded.)
pub fn chain_root(seed: u64, plan: &PrecisionPlan) -> u64 {
    let mut h = fold(0x4B56_5041_4745_4431, seed); // "KVPAGED1"
    for site in [plan.attention, plan.mlp, plan.norm, plan.sampler] {
        h = fold(h, site.mu as u64);
        h = fold(h, site.tau.to_bits() as u64);
        h = fold(h, rule_tag(site.rule));
    }
    h
}

/// A rollback point for speculative decoding: everything `truncate_to`
/// needs to restore a [`PagedKvCache`] to a prior committed length. Taken
/// at position boundaries only (no position mid-append), so the block
/// count is derivable from `len` and does not need saving.
#[derive(Debug, Clone)]
pub struct KvCheckpoint {
    /// Committed positions at checkpoint time.
    len: usize,
    /// Adopted-row count at checkpoint time.
    adopted: usize,
    /// Chain hash covering the `len` positions.
    chain: u64,
    /// Pending per-token hashes of the partial tail block.
    pending: Vec<u64>,
}

impl KvCheckpoint {
    /// Committed positions the checkpoint restores to.
    pub fn len(&self) -> usize {
        self.len
    }
}

/// A session's paged view of the pool: the block table, the running
/// token-chain hash, and the adopt / append / publish lifecycle.
pub struct PagedKvCache {
    pool: Arc<KvBlockPool>,
    blocks: Vec<PagedBlock>,
    /// Positions with complete (all-layer) rows.
    len: usize,
    /// Rows adopted from shared blocks instead of computed.
    adopted: usize,
    /// Chain root (function of the session's seed and plan).
    root: u64,
    /// Chain hash covering the `len` cached positions.
    chain: u64,
    /// Per-token chain hashes inside the current tail block (published
    /// with the block when it fills).
    pending: Vec<u64>,
    /// Positions with *staged* (appended, not yet completed) rows beyond
    /// `len` — the batched-verify window of speculative decoding. Reads
    /// may reach `len + staged`; `complete_position`/`truncate_to`/
    /// `discard_staged` drain it.
    staged: usize,
    /// Scratch mode (speculative draft): completed positions advance the
    /// chain as usual but are never published to the prefix-share index —
    /// draft rows are throwaway and must not be adoptable.
    scratch: bool,
}

impl PagedKvCache {
    pub fn new(pool: Arc<KvBlockPool>, root: u64) -> Self {
        PagedKvCache {
            pool,
            blocks: Vec::new(),
            len: 0,
            adopted: 0,
            root,
            chain: root,
            pending: Vec::new(),
            staged: 0,
            scratch: false,
        }
    }

    pub fn pool(&self) -> &Arc<KvBlockPool> {
        &self.pool
    }

    /// Cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Rows adopted from the prefix-share index (never recomputed).
    pub fn adopted(&self) -> usize {
        self.adopted
    }

    /// Cached rows (K and V counted separately): `2 · layers · len`.
    pub fn rows(&self) -> usize {
        2 * self.pool.layers * self.len
    }

    /// Rows the LAMP KV repair pinned at exact f32 across this session's
    /// blocks (adopted blocks included — their pins are resident too).
    pub fn pinned_rows(&self) -> usize {
        self.blocks.iter().map(|b| b.data().pinned_rows()).sum()
    }

    /// Pinned fraction of the rows this cache holds (`pinned / rows()`).
    /// A partially adopted shared tail may carry the origin session's pins
    /// beyond this session's own rows, so the ratio can slightly exceed
    /// the session-local pin decision rate in that (rare) configuration.
    pub fn pinned_rate(&self) -> f64 {
        let rows = self.rows();
        if rows == 0 {
            0.0
        } else {
            self.pinned_rows() as f64 / rows as f64
        }
    }

    /// Resident bytes of this session's blocks (slabs + repair annex).
    pub fn resident_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.data().resident_bytes()).sum()
    }

    /// Adopt the longest prefix of `tokens` available from the pool's
    /// prefix-share index. Walks published blocks full-block by
    /// full-block; the final match may end mid-block (the copy-on-write
    /// case when the session later appends). Only valid on an empty
    /// cache; returns the number of positions adopted.
    pub fn adopt_prefix(&mut self, tokens: &[u32]) -> usize {
        if !self.pool.sharing || self.len != 0 || tokens.is_empty() {
            return 0;
        }
        let bs = self.pool.block_size;
        let mut adopted = 0;
        loop {
            let rest = &tokens[adopted..];
            if rest.is_empty() {
                break;
            }
            let take = rest.len().min(bs);
            let mut hashes = Vec::with_capacity(take);
            let mut h = self.chain;
            for &t in &rest[..take] {
                h = fold(h, t as u64 + 1);
                hashes.push(h);
            }
            let mut hit = None;
            for j in (1..=take).rev() {
                if let Some(arc) = self.pool.lookup(hashes[j - 1]) {
                    hit = Some((j, arc));
                    break;
                }
            }
            let Some((j, arc)) = hit else { break };
            self.blocks.push(PagedBlock::Shared(arc));
            adopted += j;
            self.chain = hashes[j - 1];
            self.len = adopted;
            if j < bs {
                // Partial tail: seed the pending hashes so the block can
                // republish a full hash set after copy-on-write + refill.
                self.pending = hashes[..j].to_vec();
                break;
            }
        }
        self.adopted = adopted;
        self.pool.record_adoption(adopted);
        adopted
    }

    /// Store position `pos`'s K and V rows for `layer`. Positions are
    /// append-only (`pos >= len`): plain decode writes exactly at `len`,
    /// while a speculative batched verify *stages* a short run of
    /// positions at `len..len + m` per layer before any of them is
    /// completed (per layer the run ascends, so block allocation still
    /// happens in order on layer 0). The block is allocated on the first
    /// layer of the first position it covers, and a shared tail (partial
    /// adoption) is copied on first write. Returns the number of rows the
    /// repair rule pinned; fails with the typed resource error on pool
    /// exhaustion (no state is modified in that case).
    pub fn append_row(
        &mut self,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<usize> {
        debug_assert!(pos >= self.len, "KV rows are append-only");
        let bs = self.pool.block_size;
        let b = pos / bs;
        let slot = pos % bs;
        if layer == 0 {
            if b == self.blocks.len() {
                let blk = self.pool.alloc()?;
                self.blocks.push(PagedBlock::Owned(blk));
            } else if b + 1 == self.blocks.len() {
                if matches!(self.blocks[b], PagedBlock::Shared(_)) {
                    self.cow_tail(b, slot)?;
                }
            } else {
                return Err(Error::invariant(format!(
                    "append_row: position {pos} maps to block {b}, table has {}",
                    self.blocks.len()
                )));
            }
        }
        let data = match &mut self.blocks[b] {
            PagedBlock::Owned(d) => d,
            PagedBlock::Shared(_) => {
                return Err(Error::invariant(
                    "append_row into a shared block (copy-on-write missed)".to_string(),
                ))
            }
        };
        let pinned = data.write_row(layer, slot, k_row, v_row, self.pool.repair_tau);
        self.staged = self.staged.max(pos + 1 - self.len);
        Ok(pinned)
    }

    /// Copy-on-write: replace the shared tail block (adopted up to
    /// `valid` rows) with an owned copy before the first append into it.
    fn cow_tail(&mut self, b: usize, valid: usize) -> Result<()> {
        let mut fresh = self.pool.alloc()?;
        if let PagedBlock::Shared(src) = &self.blocks[b] {
            fresh.copy_rows_from(src, valid);
        }
        let old = std::mem::replace(&mut self.blocks[b], PagedBlock::Owned(fresh));
        self.pool.release(old);
        Ok(())
    }

    /// Mark position `pos` complete (all layers written), folding `token`
    /// into the chain. When the tail block fills on a sharing pool it is
    /// frozen and published for prefix adoption — unless the cache is in
    /// scratch (speculative-draft) mode, whose rows are throwaway and must
    /// never enter the prefix-share index.
    pub fn complete_position(&mut self, token: u32, pos: usize) {
        debug_assert_eq!(pos, self.len, "positions complete in order");
        self.chain = fold(self.chain, token as u64 + 1);
        self.pending.push(self.chain);
        self.len = pos + 1;
        self.staged = self.staged.saturating_sub(1);
        if self.len % self.pool.block_size == 0 {
            if self.pool.sharing && !self.scratch {
                match self.blocks.pop().expect("tail block exists") {
                    PagedBlock::Owned(data) => {
                        let arc = self.pool.publish(data, &self.pending);
                        self.blocks.push(PagedBlock::Shared(arc));
                    }
                    shared => self.blocks.push(shared),
                }
            }
            self.pending.clear();
        }
    }

    /// Enter / leave scratch (speculative-draft) mode. In scratch mode
    /// completed positions advance the chain normally but filled blocks
    /// are not published for prefix adoption; the caller rolls the whole
    /// extension back via [`Self::truncate_to`] afterwards.
    pub(crate) fn set_scratch(&mut self, on: bool) {
        self.scratch = on;
    }

    /// Positions with staged (appended-but-uncompleted) rows beyond
    /// [`Self::len`].
    pub fn staged(&self) -> usize {
        self.staged
    }

    /// Snapshot the commit state for a later [`Self::truncate_to`]. Only
    /// valid between positions (nothing staged).
    pub fn checkpoint(&self) -> KvCheckpoint {
        debug_assert_eq!(self.staged, 0, "checkpoint mid-append");
        KvCheckpoint {
            len: self.len,
            adopted: self.adopted,
            chain: self.chain,
            pending: self.pending.clone(),
        }
    }

    /// Roll the cache back to a checkpoint taken on this cache: release
    /// every block past the restored length, drop staged rows, clear the
    /// truncated tail slots' repair annex (accounting hygiene — the slab
    /// bytes are unreachable and the next append overwrites both), and
    /// restore the chain state. Blocks the checkpoint covered are kept
    /// as-is: committed slots are never mutated by speculation, and a
    /// draft-triggered copy-on-write of a shared tail only pessimizes
    /// sharing (the owned copy is byte-exact over the committed slots).
    pub fn truncate_to(&mut self, cp: &KvCheckpoint) {
        debug_assert!(cp.len <= self.len, "checkpoint is from this cache's past");
        let bs = self.pool.block_size;
        let needed = (cp.len + bs - 1) / bs;
        while self.blocks.len() > needed {
            let b = self.blocks.pop().expect("counted above");
            self.pool.release(b);
        }
        if cp.len % bs != 0 {
            if let Some(PagedBlock::Owned(data)) = self.blocks.last_mut() {
                data.clear_annex_from(cp.len % bs);
            }
        }
        self.len = cp.len;
        self.adopted = cp.adopted;
        self.chain = cp.chain;
        self.pending.clear();
        self.pending.extend_from_slice(&cp.pending);
        self.staged = 0;
    }

    /// Drop any staged rows beyond the committed length — the cheap
    /// truncation after a batched verify commits its accepted prefix
    /// (chain and pending already reflect exactly the completed tokens,
    /// so only the staged suffix and its annex entries go).
    pub(crate) fn discard_staged(&mut self) {
        if self.staged == 0 {
            return;
        }
        let bs = self.pool.block_size;
        let needed = (self.len + bs - 1) / bs;
        while self.blocks.len() > needed {
            let b = self.blocks.pop().expect("counted above");
            self.pool.release(b);
        }
        if self.len % bs != 0 {
            if let Some(PagedBlock::Owned(data)) = self.blocks.last_mut() {
                data.clear_annex_from(self.len % bs);
            }
        }
        self.staged = 0;
    }

    /// Release every block back to the pool, keeping the chain root — the
    /// reset primitive (`DecodeSession::reset`).
    pub fn clear(&mut self) {
        for b in self.blocks.drain(..) {
            self.pool.release(b);
        }
        self.len = 0;
        self.adopted = 0;
        self.chain = self.root;
        self.pending.clear();
        self.staged = 0;
        self.scratch = false;
    }

    /// Clear and re-key the chain for a new `(seed, plan)` binding — the
    /// reseat primitive.
    pub fn rebind(&mut self, root: u64) {
        self.clear();
        self.root = root;
        self.chain = root;
    }
}

impl Drop for PagedKvCache {
    /// A dropped session must not leak pool capacity.
    fn drop(&mut self) {
        self.clear();
    }
}

/// Compute one (head, query-row) attention unit against the paged cache —
/// the fused dequant-on-read twin of
/// [`lamp_attention_row`](super::attention::lamp_attention_row). Scores
/// are accumulated per cached block: f32-backed runs are read in place
/// (bit-identical to the contiguous kernel), quantized/pinned runs are
/// gathered into `gather` first; each score is an independent PS(μ)
/// chain, so chunking cannot change any bit. Selection, FP32 repair
/// (against the rows *as stored* — the weight-storage principle), softmax
/// and ascending-`j` value aggregation follow the contiguous kernel
/// exactly. Returns the row's [`RowLamp`] accounting.
#[allow(clippy::too_many_arguments)]
pub(crate) fn lamp_attention_row_kv(
    qi: &[f32],
    cache: &PagedKvCache,
    layer: usize,
    off: usize,
    n_keys: usize,
    scale: f32,
    prec: AttentionPrecision,
    row_seed: u64,
    scores: &mut Vec<f32>,
    gather: &mut Vec<f32>,
    out: &mut [f32],
) -> RowLamp {
    let hd = qi.len();
    debug_assert_eq!(out.len(), hd);
    debug_assert!(
        n_keys <= cache.len + cache.staged + 1,
        "reading unwritten cache rows"
    );
    let d = cache.pool.d;
    let bs = cache.pool.block_size;
    // Step 1: fused PS(μ) accumulation, per block run.
    scores.clear();
    scores.resize(n_keys, 0.0);
    let mut j0 = 0;
    while j0 < n_keys {
        let b = j0 / bs;
        let slot0 = j0 % bs;
        let run = (bs - slot0).min(n_keys - j0);
        let data = cache.blocks[b].data();
        match data.k_run_slice(layer, slot0, run) {
            Some(slab) => score_row_ps(
                qi,
                &slab[off..],
                d,
                run,
                prec.mu,
                scale,
                &mut scores[j0..j0 + run],
            ),
            None => {
                // Gather only this head's columns: values are identical
                // to a full-width gather (dequantization is per element),
                // so every score bit matches, at 1/heads of the work.
                data.gather_k_cols(layer, slot0, run, off, hd, gather);
                score_row_ps(
                    qi,
                    gather,
                    hd,
                    run,
                    prec.mu,
                    scale,
                    &mut scores[j0..j0 + run],
                );
            }
        }
        j0 += run;
    }
    // Steps 2-3: LAMP selection + FP32 recomputation over the stored rows.
    let mut row = RowLamp::default();
    if prec.tau.is_finite() {
        let mut rng = Rng::new(row_seed);
        let mask = select_softmax(scores, prec.tau, prec.rule, &mut rng);
        (row.tiles, row.tiles_total) = tile_counters(&mask, prec.rule);
        for (j, &m) in mask.iter().enumerate() {
            if m {
                let data = cache.blocks[j / bs].data();
                let kj = data.k_cols(layer, j % bs, off, hd, gather);
                scores[j] = dot_f32(qi, kj) * scale;
                row.recomputed += 1;
            }
        }
    }
    // Step 4: FP32 softmax + value aggregation in ascending j.
    softmax_inplace(scores);
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for (j, &p) in scores.iter().enumerate() {
        let data = cache.blocks[j / bs].data();
        let vj = data.v_cols(layer, j % bs, off, hd, gather);
        for (o, &vv) in out.iter_mut().zip(vj) {
            *o += p * vv;
        }
    }
    row
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::model::attention::lamp_attention_row;

    fn nano() -> ModelConfig {
        ModelConfig::nano()
    }

    fn pool(fmt: WeightFormat, tau: f32, capacity: usize, sharing: bool) -> Arc<KvBlockPool> {
        KvBlockPool::new(
            &nano(),
            KvCacheOptions {
                format: fmt,
                repair_tau: tau,
                block_size: 4,
                capacity_blocks: capacity,
                sharing,
            },
        )
        .unwrap()
    }

    fn rand_row(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.normal_f32()).collect()
    }

    /// Fill `cache` with `n` random positions (all layers), folding fake
    /// tokens; returns the written (k, v) rows per (layer, pos).
    fn fill(
        cache: &mut PagedKvCache,
        n: usize,
        layers: usize,
        d: usize,
        rng: &mut Rng,
    ) -> Vec<Vec<(Vec<f32>, Vec<f32>)>> {
        let mut rows = vec![Vec::new(); layers];
        for pos in 0..n {
            for (layer, lr) in rows.iter_mut().enumerate() {
                let k = rand_row(rng, d);
                let v = rand_row(rng, d);
                cache.append_row(layer, pos, &k, &v).unwrap();
                lr.push((k, v));
            }
            cache.complete_position((pos % 96) as u32, pos);
        }
        rows
    }

    #[test]
    fn kvstore_zeros_format_bytes() {
        for fmt in [
            WeightFormat::F32,
            WeightFormat::Bf16,
            WeightFormat::PsRounded { mu: 5 },
        ] {
            let s = KvStore::zeros(fmt, 12);
            assert_eq!(s.format(), fmt);
            assert_eq!(s.resident_bytes(), 12 * fmt.bytes_per_element());
        }
    }

    #[test]
    fn write_read_roundtrip_and_error_signal() {
        let mut rng = Rng::new(1);
        let row: Vec<f32> = rand_row(&mut rng, 8);
        // f32: exact, zero error.
        let mut s = KvStore::zeros(WeightFormat::F32, 8);
        assert_eq!(s.write_row(0, &row), 0.0);
        let mut out = Vec::new();
        s.extend_dequant(0, 8, &mut out);
        assert_eq!(out, row);
        // bf16: error matches the widened round trip, dequant is exact.
        let mut s = KvStore::zeros(WeightFormat::Bf16, 8);
        let err = s.write_row(0, &row);
        let want: f32 = row
            .iter()
            .map(|&x| (x - bf16_to_f32(f32_to_bf16(x))).abs())
            .fold(0.0, f32::max);
        assert_eq!(err, want);
        assert!(err > 0.0, "random rows are not bf16-representable");
        out.clear();
        s.extend_dequant(0, 8, &mut out);
        for (a, &x) in out.iter().zip(&row) {
            assert_eq!(a.to_bits(), bf16_to_f32(f32_to_bf16(x)).to_bits());
        }
        // ps(3): rounded storage.
        let mut s = KvStore::zeros(WeightFormat::PsRounded { mu: 3 }, 8);
        let err = s.write_row(0, &row);
        assert!(err > 0.0);
        out.clear();
        s.extend_dequant(0, 8, &mut out);
        for (a, &x) in out.iter().zip(&row) {
            assert_eq!(a.to_bits(), round_to_mantissa(x, 3).to_bits());
        }
    }

    #[test]
    fn repair_pins_high_error_rows_and_tau_zero_is_exact() {
        let cfg = nano();
        let d = cfg.d_model;
        let mut rng = Rng::new(2);
        // tau = 0: every inexact row pinned, reads are bitwise exact.
        let p = pool(WeightFormat::PsRounded { mu: 2 }, 0.0, 8, false);
        let mut cache = PagedKvCache::new(p, 7);
        let rows = fill(&mut cache, 6, cfg.layers, d, &mut rng);
        assert!(cache.pinned_rows() > 0);
        let mut scratch = Vec::new();
        for (layer, lr) in rows.iter().enumerate() {
            for (pos, (k, v)) in lr.iter().enumerate() {
                let data = cache.blocks[pos / 4].data();
                assert_eq!(data.k_row(layer, pos % 4, &mut scratch), &k[..]);
                assert_eq!(data.v_row(layer, pos % 4, &mut scratch), &v[..]);
            }
        }
        // tau = inf: nothing pinned, reads are the quantized values.
        let p = pool(WeightFormat::PsRounded { mu: 2 }, f32::INFINITY, 8, false);
        let mut cache = PagedKvCache::new(p, 7);
        let mut rng = Rng::new(2);
        let rows = fill(&mut cache, 6, cfg.layers, d, &mut rng);
        assert_eq!(cache.pinned_rows(), 0);
        let data = cache.blocks[0].data();
        let got = data.k_row(0, 0, &mut scratch);
        for (g, &x) in got.iter().zip(&rows[0][0].0) {
            assert_eq!(g.to_bits(), round_to_mantissa(x, 2).to_bits());
        }
        // Finite tau pins a strict subset between the two extremes; derive
        // it as the median of the realized row errors so the split is
        // guaranteed nondegenerate.
        let row_err = |row: &[f32]| -> f32 {
            row.iter()
                .map(|&x| (x - round_to_mantissa(x, 2)).abs())
                .fold(0.0, f32::max)
        };
        let mut errs: Vec<f32> = Vec::new();
        for lr in &rows {
            for (k, v) in lr {
                errs.push(row_err(k));
                errs.push(row_err(v));
            }
        }
        errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let tau = errs[errs.len() / 2];
        let p = pool(WeightFormat::PsRounded { mu: 2 }, tau, 8, false);
        let mut cache = PagedKvCache::new(p, 7);
        let mut rng = Rng::new(2);
        fill(&mut cache, 6, cfg.layers, d, &mut rng);
        let pinned = cache.pinned_rows();
        assert!(pinned > 0 && pinned < cache.rows(), "pinned={pinned}");
        assert!(cache.pinned_rate() > 0.0 && cache.pinned_rate() < 1.0);
        // Pinned rows cost f32 bytes in the resident accounting.
        assert!(cache.resident_bytes() > 0);
    }

    #[test]
    fn pool_alloc_release_accounting() {
        let p = pool(WeightFormat::F32, f32::INFINITY, 3, false);
        let root = 1u64;
        let mut c1 = PagedKvCache::new(p.clone(), root);
        let mut c2 = PagedKvCache::new(p.clone(), root);
        let d = nano().d_model;
        let row = vec![0.5f32; d];
        // 4-position blocks: 5 positions -> 2 blocks.
        for pos in 0..5 {
            for l in 0..nano().layers {
                c1.append_row(l, pos, &row, &row).unwrap();
            }
            c1.complete_position(0, pos);
        }
        assert_eq!(p.stats().used_blocks, 2);
        for l in 0..nano().layers {
            c2.append_row(l, 0, &row, &row).unwrap();
        }
        c2.complete_position(0, 0);
        assert_eq!(p.stats().used_blocks, 3);
        assert_eq!(p.available_blocks(), 0);
        // Exhaustion is a typed resource error and mutates nothing.
        let mut c3 = PagedKvCache::new(p.clone(), root);
        let err = c3.append_row(0, 0, &row, &row).unwrap_err();
        assert!(err.is_resource(), "{err}");
        assert_eq!(p.stats().used_blocks, 3);
        // Releases return the pool to empty; buffers are recycled.
        c1.clear();
        c2.clear();
        drop(c3);
        let st = p.stats();
        assert_eq!(st.used_blocks, 0);
        assert_eq!(st.free_buffers, 3);
        // A fresh cache reuses a recycled buffer (no growth past capacity).
        let mut c4 = PagedKvCache::new(p.clone(), root);
        c4.append_row(0, 0, &row, &row).unwrap();
        assert_eq!(p.stats().used_blocks, 1);
    }

    #[test]
    fn drop_releases_blocks() {
        let p = pool(WeightFormat::F32, f32::INFINITY, 2, false);
        {
            let mut c = PagedKvCache::new(p.clone(), 3);
            let row = vec![1.0f32; nano().d_model];
            for l in 0..nano().layers {
                c.append_row(l, 0, &row, &row).unwrap();
            }
            assert_eq!(p.stats().used_blocks, 1);
        }
        assert_eq!(p.stats().used_blocks, 0, "Drop must not leak blocks");
    }

    #[test]
    fn publish_adopt_full_and_partial_with_cow() {
        let cfg = nano();
        let d = cfg.d_model;
        let p = pool(WeightFormat::F32, f32::INFINITY, 6, true);
        let root = chain_root(9, &PrecisionPlan::reference());
        let tokens: Vec<u32> = (0..10u32).collect();
        let mut writer = PagedKvCache::new(p.clone(), root);
        let mut rng = Rng::new(4);
        // Deterministic rows keyed by (layer, pos) so a reader session
        // would write identical rows — mirrors real decode determinism.
        let mut rows: Vec<Vec<(Vec<f32>, Vec<f32>)>> = vec![Vec::new(); cfg.layers];
        for (pos, &t) in tokens.iter().enumerate() {
            for (layer, lr) in rows.iter_mut().enumerate() {
                let k = rand_row(&mut rng, d);
                let v = rand_row(&mut rng, d);
                writer.append_row(layer, pos, &k, &v).unwrap();
                lr.push((k, v));
            }
            writer.complete_position(t, pos);
        }
        // 10 positions, block 4: blocks 0 and 1 published, tail partial.
        assert_eq!(p.stats().cached_blocks, 2);
        writer.clear();
        assert_eq!(p.stats().used_blocks, 2, "published blocks outlive the session");

        // Full-block adoption: identical prefix of 8 tokens.
        let mut reader = PagedKvCache::new(p.clone(), root);
        let adopted = reader.adopt_prefix(&tokens[..9]);
        assert_eq!(adopted, 8, "two full blocks adopt; the 9th was never published");
        assert_eq!(reader.len(), 8);
        let mut scratch = Vec::new();
        for layer in 0..cfg.layers {
            for pos in 0..8 {
                let data = reader.blocks[pos / 4].data();
                assert_eq!(
                    data.k_row(layer, pos % 4, &mut scratch),
                    &rows[layer][pos].0[..]
                );
            }
        }

        // Partial adoption ends mid-block and triggers copy-on-write on
        // the next append.
        let mut partial = PagedKvCache::new(p.clone(), root);
        let adopted = partial.adopt_prefix(&tokens[..6]);
        assert_eq!(adopted, 6, "4 full + 2 rows into the second published block");
        assert!(matches!(partial.blocks[1], PagedBlock::Shared(_)));
        let k = rand_row(&mut rng, d);
        let v = rand_row(&mut rng, d);
        for layer in 0..cfg.layers {
            partial.append_row(layer, 6, &k, &v).unwrap();
        }
        assert!(
            matches!(partial.blocks[1], PagedBlock::Owned(_)),
            "append into a shared tail must copy-on-write"
        );
        // The copied rows survived the CoW byte-for-byte.
        let data = partial.blocks[1].data();
        assert_eq!(data.k_row(0, 1, &mut scratch), &rows[0][5].0[..]);
        assert_eq!(data.k_row(0, 2, &mut scratch), &k[..]);

        // A different root (other seed/plan) adopts nothing.
        let mut other = PagedKvCache::new(p.clone(), root ^ 1);
        assert_eq!(other.adopt_prefix(&tokens), 0);
        let st = p.stats();
        assert!(st.share_hits >= 2 && st.share_lookups >= 3);
        assert!(st.shared_rows >= 14);
    }

    #[test]
    fn eviction_reclaims_cached_blocks_under_pressure() {
        let cfg = nano();
        let d = cfg.d_model;
        let p = pool(WeightFormat::F32, f32::INFINITY, 2, true);
        let mut a = PagedKvCache::new(p.clone(), 5);
        let row = vec![0.25f32; d];
        // Two full 4-position blocks, both published to the prompt cache.
        for pos in 0..8 {
            for l in 0..cfg.layers {
                a.append_row(l, pos, &row, &row).unwrap();
            }
            a.complete_position(pos as u32, pos);
        }
        a.clear();
        // Both blocks cached and unreferenced; a new session must evict to
        // allocate.
        assert_eq!(p.stats().used_blocks, 2);
        assert_eq!(p.available_blocks(), 2);
        let mut b = PagedKvCache::new(p.clone(), 6);
        for l in 0..cfg.layers {
            b.append_row(l, 0, &row, &row).unwrap();
        }
        let st = p.stats();
        assert!(st.evictions >= 1, "allocation under pressure must evict");
        assert_eq!(st.used_blocks, 2);
        drop(b);
        assert_eq!(p.evict_unused(), 1);
        assert_eq!(p.stats().used_blocks, 0);
    }

    #[test]
    fn paged_attention_row_bit_identical_to_contiguous_f32() {
        // The kernel contract: against f32-backed paging, every rule and
        // precision reproduces the contiguous Matrix kernel bit for bit —
        // per-block score runs cannot change independent chains.
        let cfg = nano();
        let d = cfg.d_model;
        let heads = cfg.heads;
        let hd = d / heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut rng = Rng::new(11);
        let n = 11; // crosses two block boundaries at block_size 4
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let q: Vec<f32> = rand_row(&mut rng, d);
        let p = pool(WeightFormat::F32, f32::INFINITY, 4, false);
        let mut cache = PagedKvCache::new(p, 1);
        for pos in 0..n {
            for layer in 0..cfg.layers {
                // Use layer 0 as the one under test; others get noise.
                if layer == 0 {
                    cache.append_row(layer, pos, k.row(pos), v.row(pos)).unwrap();
                } else {
                    cache.append_row(layer, pos, v.row(pos), k.row(pos)).unwrap();
                }
            }
            cache.complete_position(pos as u32, pos);
        }
        for prec in [
            AttentionPrecision::reference(),
            AttentionPrecision::uniform(4),
            AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Strict),
            AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Random),
            AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Relaxed),
            AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Tile { width: 3 }),
            AttentionPrecision::lamp(4, 0.05, SoftmaxRule::TileRandom { width: 3 }),
        ] {
            for h in 0..heads {
                let off = h * hd;
                let mut scores_a = Vec::new();
                let mut out_a = vec![0.0f32; hd];
                let na = lamp_attention_row(
                    &q[off..off + hd],
                    &k,
                    &v,
                    off,
                    n,
                    scale,
                    prec,
                    99,
                    &mut scores_a,
                    &mut out_a,
                );
                let mut scores_b = Vec::new();
                let mut gather = Vec::new();
                let mut out_b = vec![0.0f32; hd];
                let nb = lamp_attention_row_kv(
                    &q[off..off + hd],
                    &cache,
                    0,
                    off,
                    n,
                    scale,
                    prec,
                    99,
                    &mut scores_b,
                    &mut gather,
                    &mut out_b,
                );
                assert_eq!(na, nb, "recompute counts diverge");
                for (a, b) in out_a.iter().zip(&out_b) {
                    assert_eq!(a.to_bits(), b.to_bits(), "paged f32 != contiguous");
                }
            }
        }
    }

    #[test]
    fn quantized_kv_attention_matches_dequantized_oracle() {
        // Fused dequant-on-read ≡ dequantize-then-f32-cache: build a bf16
        // cache and an f32 cache holding exactly the dequantized (or
        // pinned-exact) values; the kernel outputs must agree bitwise.
        let cfg = nano();
        let d = cfg.d_model;
        let hd = d / cfg.heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut rng = Rng::new(13);
        let n = 7;
        for (fmt, tau) in [
            (WeightFormat::Bf16, f32::INFINITY),
            (WeightFormat::Bf16, 0.004),
            (WeightFormat::PsRounded { mu: 3 }, 0.05),
        ] {
            let p = pool(fmt, tau, 4, false);
            let pf = pool(WeightFormat::F32, f32::INFINITY, 4, false);
            let mut cache = PagedKvCache::new(p, 1);
            let mut oracle = PagedKvCache::new(pf, 1);
            let mut scratch = Vec::new();
            for pos in 0..n {
                for layer in 0..cfg.layers {
                    let kr = rand_row(&mut rng, d);
                    let vr = rand_row(&mut rng, d);
                    cache.append_row(layer, pos, &kr, &vr).unwrap();
                    // Mirror the *stored* values into the f32 oracle.
                    let data = cache.blocks[pos / 4].data();
                    let ks = data.k_row(layer, pos % 4, &mut scratch).to_vec();
                    let vs = data.v_row(layer, pos % 4, &mut scratch).to_vec();
                    oracle.append_row(layer, pos, &ks, &vs).unwrap();
                }
                cache.complete_position(pos as u32, pos);
                oracle.complete_position(pos as u32, pos);
            }
            let q: Vec<f32> = rand_row(&mut rng, d);
            for prec in [
                AttentionPrecision::reference(),
                AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Strict),
            ] {
                let (mut sa, mut sb) = (Vec::new(), Vec::new());
                let (mut ga, mut gb) = (Vec::new(), Vec::new());
                let mut oa = vec![0.0f32; hd];
                let mut ob = vec![0.0f32; hd];
                let na = lamp_attention_row_kv(
                    &q[..hd], &cache, 1, 0, n, scale, prec, 7, &mut sa, &mut ga, &mut oa,
                );
                let nb = lamp_attention_row_kv(
                    &q[..hd], &oracle, 1, 0, n, scale, prec, 7, &mut sb, &mut gb, &mut ob,
                );
                assert_eq!(na, nb, "{fmt:?}");
                for (a, b) in oa.iter().zip(&ob) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{fmt:?} fused != dequantized");
                }
            }
        }
    }

    #[test]
    fn chain_root_distinguishes_seed_and_plan() {
        let r = PrecisionPlan::reference();
        let w = PrecisionPlan::whole_model(AttentionPrecision::lamp(
            3,
            0.1,
            SoftmaxRule::Strict,
        ));
        assert_ne!(chain_root(1, &r), chain_root(2, &r));
        assert_ne!(chain_root(1, &r), chain_root(1, &w));
        assert_eq!(chain_root(1, &w), chain_root(1, &w));
    }

    #[test]
    fn checkpoint_truncate_restores_state_and_releases_blocks() {
        let cfg = nano();
        let d = cfg.d_model;
        // tau = 0 pins every inexact row, making the annex-hygiene part of
        // the rollback observable through pinned_rows().
        let p = pool(WeightFormat::PsRounded { mu: 2 }, 0.0, 8, true);
        let root = chain_root(3, &PrecisionPlan::reference());
        let mut cache = PagedKvCache::new(p.clone(), root);
        let mut rng = Rng::new(21);
        fill(&mut cache, 6, cfg.layers, d, &mut rng);
        let cp = cache.checkpoint();
        assert_eq!(cp.len(), 6);
        let (len0, chain0, pending0) = (cache.len, cache.chain, cache.pending.clone());
        let (pinned0, used0, cached0) =
            (cache.pinned_rows(), p.stats().used_blocks, p.stats().cached_blocks);
        // Draft extension in scratch mode, crossing a block boundary.
        cache.set_scratch(true);
        for pos in 6..11 {
            for l in 0..cfg.layers {
                let k = rand_row(&mut rng, d);
                let v = rand_row(&mut rng, d);
                cache.append_row(l, pos, &k, &v).unwrap();
            }
            cache.complete_position((pos % 96) as u32, pos);
        }
        cache.set_scratch(false);
        assert!(p.stats().used_blocks > used0, "draft grew the block table");
        assert_eq!(
            p.stats().cached_blocks,
            cached0,
            "scratch mode must not publish draft blocks for adoption"
        );
        cache.truncate_to(&cp);
        assert_eq!(cache.len(), len0);
        assert_eq!(cache.chain, chain0);
        assert_eq!(cache.pending, pending0);
        assert_eq!(cache.staged(), 0);
        assert_eq!(p.stats().used_blocks, used0, "rollback returns draft blocks");
        assert_eq!(
            cache.pinned_rows(),
            pinned0,
            "truncated slots' annex entries are cleared"
        );
        // Post-rollback appends behave exactly like a never-speculated
        // cache: the tail block fills and publishes with a full hash set.
        for pos in 6..8 {
            for l in 0..cfg.layers {
                let k = rand_row(&mut rng, d);
                let v = rand_row(&mut rng, d);
                cache.append_row(l, pos, &k, &v).unwrap();
            }
            cache.complete_position((pos % 96) as u32, pos);
        }
        assert_eq!(cache.len(), 8);
        assert_eq!(
            p.stats().cached_blocks,
            cached0 + 1,
            "the refilled tail block publishes normally"
        );
        drop(cache);
        assert_eq!(p.stats().used_blocks, p.stats().cached_blocks);
    }

    #[test]
    fn staged_appends_read_back_and_discard_releases_tail() {
        let cfg = nano();
        let d = cfg.d_model;
        let p = pool(WeightFormat::F32, f32::INFINITY, 8, false);
        let mut cache = PagedKvCache::new(p.clone(), 5);
        let mut rng = Rng::new(31);
        fill(&mut cache, 3, cfg.layers, d, &mut rng);
        // Stage positions 3..6 in batched-verify order: per layer, the
        // whole ascending run, before any position completes.
        let mut staged: Vec<Vec<(Vec<f32>, Vec<f32>)>> = vec![Vec::new(); cfg.layers];
        for (l, lr) in staged.iter_mut().enumerate() {
            for pos in 3..6 {
                let k = rand_row(&mut rng, d);
                let v = rand_row(&mut rng, d);
                cache.append_row(l, pos, &k, &v).unwrap();
                lr.push((k, v));
            }
        }
        assert_eq!(cache.staged(), 3);
        assert_eq!(cache.len(), 3);
        assert_eq!(p.stats().used_blocks, 2, "staging allocated the next block");
        // Staged rows are readable in place (f32 pool: byte-exact).
        let mut scratch = Vec::new();
        for l in 0..cfg.layers {
            for pos in 3..6 {
                let data = cache.blocks[pos / 4].data();
                assert_eq!(
                    data.k_row(l, pos % 4, &mut scratch),
                    &staged[l][pos - 3].0[..]
                );
            }
        }
        // Commit the first staged position, discard the rest.
        cache.complete_position(40, 3);
        assert_eq!(cache.len(), 4);
        cache.discard_staged();
        assert_eq!(cache.staged(), 0);
        assert_eq!(p.stats().used_blocks, 1, "discard releases the staged tail block");
        cache.clear();
        assert_eq!(p.stats().used_blocks, 0);
    }

    #[test]
    fn options_validate() {
        let cfg = nano();
        assert!(KvCacheOptions::private(&cfg).validate().is_ok());
        assert!(KvCacheOptions::serving(&cfg, WeightFormat::Bf16, 4)
            .validate()
            .is_ok());
        let mut bad = KvCacheOptions::private(&cfg);
        bad.block_size = 0;
        assert!(bad.validate().is_err());
        let mut bad = KvCacheOptions::private(&cfg);
        bad.capacity_blocks = 0;
        assert!(bad.validate().is_err());
        let mut bad = KvCacheOptions::private(&cfg);
        bad.repair_tau = f32::NAN;
        assert!(bad.validate().is_err());
        let mut bad = KvCacheOptions::private(&cfg);
        bad.format = WeightFormat::PsRounded { mu: 0 };
        assert!(bad.validate().is_err());
        // tau = 0 pins bitwise-exact storage (valid, documented).
        assert!(KvCacheOptions::private(&cfg).with_repair_tau(0.0).validate().is_ok());
    }
}
