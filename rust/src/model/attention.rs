//! Causal multi-head attention with LAMP mixed-precision KQ accumulation —
//! the paper's §4.2 experimental setting, instrumented and parallel.
//!
//! Per head and per query row i:
//! 1. Accumulate the causal KQ inner products y_j = ⟨q_i, k_j⟩ (j ≤ i) in
//!    PS(μ) with per-step rounding (fused row kernel
//!    [`crate::softfloat::dot::score_row_ps`]), then scale by 1/√d_h in FP32.
//! 2. Apply the LAMP selection rule to the scaled row.
//! 3. Recompute the flagged inner products in FP32 (and rescale).
//! 4. FP32 softmax over the row; FP32 value aggregation.
//!
//! `AttentionPrecision::reference()` (μ=23) reproduces uniform FP32
//! accumulation bit-for-bit; `tau = ∞` reproduces uniform PS(μ).
//!
//! Attention consumes post-projection *activations* (q/k/v are always f32
//! `Matrix` rows); mixed-precision weight storage
//! ([`crate::linalg::WeightTensor`]) enters upstream, in the QKV/proj
//! matvecs of `forward`/`DecodeSession` — by the time scores are
//! accumulated, any storage quantization is already baked into exact-f32
//! q/k/v values, so every kernel here is storage-agnostic.
//!
//! ## Execution model
//!
//! Every (head, query-row) pair is an independent unit of work: its scores
//! depend only on q/k/v and its `SoftmaxRule::Random` draws come from a
//! private RNG stream derived from `(seed, head, row)` — see
//! [`row_stream_seed`]. Nothing is shared between rows, so the sequential
//! loop ([`causal_attention`]) and the pool-parallel tiling
//! ([`causal_attention_into`] with a pool) are **bit-identical** by
//! construction, for every rule including `Random`. (The seed engine
//! instead threaded one mutable RNG through all heads of a layer, which
//! made head iteration order load-bearing and unparallelizable.)

use crate::lamp::softmax::{select_softmax, softmax_inplace, tile_count, SoftmaxRule};
use crate::linalg::Matrix;
use crate::softfloat::dot::{dot_f32, score_row_ps};
use crate::util::{Rng, ThreadPool};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Precision configuration of one composition site: (μ, τ, rule).
///
/// Historically this configured attention only; with the whole-model
/// [`PrecisionPlan`](super::plan::PrecisionPlan) the same triple now
/// describes every LAMP site (attention scores, MLP fc→GELU, final
/// norm, sampler softmax) — `model::plan` re-exports it as
/// `SitePrecision`. The name is kept for the attention-first API.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttentionPrecision {
    /// Mantissa bits for the site's PS(μ) accumulation (23 = FP32).
    pub mu: u32,
    /// LAMP threshold; `f32::INFINITY` disables recomputation.
    pub tau: f32,
    /// Selection rule.
    pub rule: SoftmaxRule,
}

impl AttentionPrecision {
    /// Uniform FP32 accumulation (the paper's reference model).
    pub fn reference() -> Self {
        AttentionPrecision { mu: 23, tau: f32::INFINITY, rule: SoftmaxRule::Strict }
    }

    /// Uniform PS(μ) accumulation, no recomputation.
    pub fn uniform(mu: u32) -> Self {
        AttentionPrecision { mu, tau: f32::INFINITY, rule: SoftmaxRule::Strict }
    }

    /// LAMP with the given rule.
    pub fn lamp(mu: u32, tau: f32, rule: SoftmaxRule) -> Self {
        AttentionPrecision { mu, tau, rule }
    }

    /// True when this site runs the exact FP32 reference computation
    /// (μ = 23, no recomputation): the engine then dispatches to the
    /// pre-plan fast kernels, which is what makes an all-reference
    /// [`PrecisionPlan`](super::plan::PrecisionPlan) bit-identical to the
    /// attention-only engine.
    pub fn is_reference(self) -> bool {
        self.mu == 23 && self.tau.is_infinite() && self.tau > 0.0
    }
}

/// Recompute accounting for one non-attention composition site
/// (MLP activation, final norm, sampler softmax).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SiteStats {
    /// Outputs recomputed in FP32 at this site.
    pub recomputed: usize,
    /// Total outputs the site evaluated (counted whether or not the site
    /// was active, so rates are comparable across plans).
    pub total: usize,
}

impl SiteStats {
    /// Recomputation rate = recomputed / total (0 when nothing evaluated).
    pub fn rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.recomputed as f64 / self.total as f64
        }
    }

    /// Merge another pass's counters.
    pub fn merge(&mut self, other: &SiteStats) {
        self.recomputed += other.recomputed;
        self.total += other.total;
    }

    /// Counters scaled by `s` (pro-rata padding attribution in the server).
    pub fn scaled(&self, s: f64) -> SiteStats {
        SiteStats {
            recomputed: (self.recomputed as f64 * s).round() as usize,
            total: (self.total as f64 * s).round() as usize,
        }
    }
}

/// Per-row LAMP accounting returned by the attention row kernels (PR 8):
/// the recomputed KQ products plus tile-selection counters. The tile
/// counters are zero for every non-tile rule, so aggregated rates stay
/// comparable across plans.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RowLamp {
    /// KQ inner products recomputed in FP32 on this row.
    pub recomputed: usize,
    /// Score tiles recomputed exactly (tile rules only).
    pub tiles: usize,
    /// Score tiles partitioning the row (tile rules only; 0 otherwise).
    pub tiles_total: usize,
}

impl RowLamp {
    /// Accumulate another row's counters.
    pub fn merge(&mut self, other: RowLamp) {
        self.recomputed += other.recomputed;
        self.tiles += other.tiles;
        self.tiles_total += other.tiles_total;
    }
}

/// Self-speculative decoding accounting (DESIGN.md §Speculative
/// decoding): how much look-ahead work the draft plan did and how much of
/// it the batched target-plan verification accepted. These counters live
/// *next to* the compute counters, never inside them — the compute fields
/// of a speculative session's stats stay bit-identical to the solo
/// non-speculative decode (only verified-and-committed rows are merged),
/// so parity suites compare compute fields while throughput dashboards
/// read these.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Speculation rounds completed (one batched verify each).
    pub rounds: usize,
    /// Draft tokens proposed across all rounds (the round's base token is
    /// not a draft and is not counted).
    pub drafted: usize,
    /// Draft tokens accepted by verification.
    pub accepted: usize,
    /// Draft forward steps executed under the draft plan.
    pub draft_steps: usize,
    /// Batched target-plan verify passes executed.
    pub verify_chunks: usize,
    /// Acceptance-length histogram: `accept_hist[i]` counts rounds that
    /// emitted `i + 1` tokens (the base token, the accepted drafts, plus
    /// the bonus token when every draft matched).
    pub accept_hist: Vec<usize>,
}

impl SpecStats {
    /// Fraction of drafted tokens the verifier accepted (0 when nothing
    /// was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Mean tokens emitted per speculation round (0 without rounds).
    pub fn mean_accept_len(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        let emitted: usize =
            self.accept_hist.iter().enumerate().map(|(i, &c)| (i + 1) * c).sum();
        emitted as f64 / self.rounds as f64
    }

    /// Account one completed round: `drafted` look-ahead tokens proposed,
    /// `accepted` of them verified, `emitted` tokens produced (base +
    /// accepted + possible bonus).
    pub fn record_round(&mut self, drafted: usize, accepted: usize, emitted: usize) {
        debug_assert!(emitted >= 1 && accepted <= drafted);
        self.rounds += 1;
        self.drafted += drafted;
        self.accepted += accepted;
        self.draft_steps += drafted;
        self.verify_chunks += 1;
        if self.accept_hist.len() < emitted {
            self.accept_hist.resize(emitted, 0);
        }
        self.accept_hist[emitted - 1] += 1;
    }

    /// Merge another session's speculation counters.
    pub fn merge(&mut self, other: &SpecStats) {
        self.rounds += other.rounds;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.draft_steps += other.draft_steps;
        self.verify_chunks += other.verify_chunks;
        if self.accept_hist.len() < other.accept_hist.len() {
            self.accept_hist.resize(other.accept_hist.len(), 0);
        }
        for (i, &c) in other.accept_hist.iter().enumerate() {
            self.accept_hist[i] += c;
        }
    }
}

/// Recomputation statistics accumulated over a forward pass, per
/// composition site. The attention counters keep their historical flat
/// names (`recomputed`/`causal_total`/`per_layer`); the sites added by the
/// whole-model [`PrecisionPlan`](super::plan::PrecisionPlan) each get a
/// [`SiteStats`].
#[derive(Debug, Clone, Default)]
pub struct LampStats {
    /// KQ inner products recomputed in FP32 (attention site).
    pub recomputed: usize,
    /// Total KQ inner products in the causal mask (attention site).
    pub causal_total: usize,
    /// Per-layer attention recomputation counts.
    pub per_layer: Vec<usize>,
    /// MLP fc→GELU site: fc inner products repaired / evaluated.
    pub mlp: SiteStats,
    /// Final-norm site: residual components restored / evaluated.
    pub norm: SiteStats,
    /// Sampler-softmax site: logit inner products repaired / evaluated.
    pub sampler: SiteStats,
    /// Attention tile counters: tiles recomputed exactly / tiles evaluated
    /// (populated only when a tile rule is active on the attention site).
    pub tiles: SiteStats,
    /// Speculative-decoding acceptance counters (zero unless the plan
    /// carries a [`SpecConfig`](super::plan::SpecConfig)). Kept separate
    /// from the compute counters so speculative sessions stay comparable
    /// to solo decode field-for-field.
    pub spec: SpecStats,
}

impl LampStats {
    /// Attention recomputation rate = recomputed / causal_total.
    pub fn rate(&self) -> f64 {
        if self.causal_total == 0 {
            0.0
        } else {
            self.recomputed as f64 / self.causal_total as f64
        }
    }

    /// (site label, recompute rate) for every composition site, in the
    /// fixed order attention, mlp, norm, sampler, attention_tiles — the
    /// serving metrics key.
    pub fn site_rates(&self) -> Vec<(String, f64)> {
        vec![
            ("attention".to_string(), self.rate()),
            ("mlp".to_string(), self.mlp.rate()),
            ("norm".to_string(), self.norm.rate()),
            ("sampler".to_string(), self.sampler.rate()),
            ("attention_tiles".to_string(), self.tiles.rate()),
        ]
    }

    /// Merge another pass's statistics (layer-wise aligned).
    pub fn merge(&mut self, other: &LampStats) {
        self.recomputed += other.recomputed;
        self.causal_total += other.causal_total;
        if self.per_layer.len() < other.per_layer.len() {
            self.per_layer.resize(other.per_layer.len(), 0);
        }
        for (i, &c) in other.per_layer.iter().enumerate() {
            self.per_layer[i] += c;
        }
        self.mlp.merge(&other.mlp);
        self.norm.merge(&other.norm);
        self.sampler.merge(&other.sampler);
        self.tiles.merge(&other.tiles);
        self.spec.merge(&other.spec);
    }

    /// Account one incremental attention row (KV-cache decode): `n_keys`
    /// causal products on `layer`, with the row kernel's [`RowLamp`]
    /// accounting (recomputed products plus tile counters).
    pub fn add_row(&mut self, layer: usize, n_keys: usize, row: RowLamp) {
        self.causal_total += n_keys;
        self.recomputed += row.recomputed;
        self.tiles.recomputed += row.tiles;
        self.tiles.total += row.tiles_total;
        if self.per_layer.len() <= layer {
            self.per_layer.resize(layer + 1, 0);
        }
        self.per_layer[layer] += row.recomputed;
    }
}

/// Derive the private RNG stream id for one (attention-call seed, head,
/// query-row) triple. Deterministic and order-independent: the stream
/// depends only on the triple, never on which thread or in which order the
/// row is processed. The caller folds the layer index into `seed` (see
/// `forward::layer_seed`), making the full derivation
/// (seed, layer, head, row) as the engine contract requires.
#[inline]
pub fn row_stream_seed(seed: u64, head: usize, row: usize) -> u64 {
    // Distinct odd multipliers keep (head, row) and (row, head) apart;
    // Rng::new splitmixes the result, so simple xor-folding suffices.
    seed ^ (head as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
        ^ (row as u64 + 1).wrapping_mul(0xD1B54A32D192ED03)
}

/// Derive the [`RowLamp`] tile counters from a selection mask. Tile masks
/// are tile-uniform (`select_tile` fills whole tiles), so the tile's first
/// element witnesses the whole tile; non-tile rules report zero tiles.
#[inline]
pub(crate) fn tile_counters(mask: &[bool], rule: SoftmaxRule) -> (usize, usize) {
    match rule {
        SoftmaxRule::Tile { width } | SoftmaxRule::TileRandom { width } => {
            let w = width.max(1);
            let total = tile_count(mask.len(), w);
            let sel = (0..total).filter(|&t| mask[t * w]).count();
            (sel, total)
        }
        _ => (0, 0),
    }
}

/// Compute one (head, query-row) attention unit into `out` (the head's
/// `hd`-wide slice of the output row). `scores` is caller-owned scratch —
/// reused across calls, so the steady state allocates nothing (except the
/// selection mask when a finite-τ LAMP rule is active).
///
/// Returns the row's [`RowLamp`] accounting.
#[allow(clippy::too_many_arguments)]
pub(crate) fn lamp_attention_row(
    qi: &[f32],
    k: &Matrix,
    v: &Matrix,
    off: usize,
    n_keys: usize,
    scale: f32,
    prec: AttentionPrecision,
    row_seed: u64,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) -> RowLamp {
    let hd = qi.len();
    debug_assert_eq!(out.len(), hd);
    debug_assert!(n_keys <= k.rows());
    // Step 1: fused PS(μ) accumulation of the causal row, FP32 scaling.
    scores.clear();
    scores.resize(n_keys, 0.0);
    score_row_ps(qi, &k.data()[off..], k.cols(), n_keys, prec.mu, scale, scores);
    // Steps 2–3: LAMP selection + FP32 recomputation.
    let mut row = RowLamp::default();
    if prec.tau.is_finite() {
        let mut rng = Rng::new(row_seed);
        let mask = select_softmax(scores, prec.tau, prec.rule, &mut rng);
        (row.tiles, row.tiles_total) = tile_counters(&mask, prec.rule);
        for (j, &m) in mask.iter().enumerate() {
            if m {
                let kj = &k.row(j)[off..off + hd];
                scores[j] = dot_f32(qi, kj) * scale;
                row.recomputed += 1;
            }
        }
    }
    // Step 4: FP32 softmax + value aggregation.
    softmax_inplace(scores);
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for (j, &p) in scores.iter().enumerate() {
        let vj = &v.row(j)[off..off + hd];
        for (o, &vv) in out.iter_mut().zip(vj) {
            *o += p * vv;
        }
    }
    row
}

/// Raw output pointer handed to the worker tiles. Each tile writes a
/// disjoint set of (row, head-column-range) slices, so the aliasing is
/// benign; `Send + Sync` are asserted on that basis.
#[derive(Clone, Copy)]
struct TileOut(*mut f32);
unsafe impl Send for TileOut {}
unsafe impl Sync for TileOut {}

/// Causal multi-head attention for one sequence, written into a reusable
/// output matrix (resized to [S, d]; allocation-free once warm).
///
/// With `pool: Some(..)` the (head × query-row) units are tiled across the
/// pool via [`ThreadPool::scope_run`]; with `None` they run inline. Both
/// paths execute the identical per-row kernel with identical per-row RNG
/// streams, so outputs and recomputation counts are bit-identical.
///
/// Returns the aggregated [`RowLamp`] accounting.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_into(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    heads: usize,
    prec: AttentionPrecision,
    seed: u64,
    pool: Option<&ThreadPool>,
    out: &mut Matrix,
) -> RowLamp {
    let _t = crate::obs::timers::scoped(crate::obs::timers::Site::Attention);
    let s = q.rows();
    let d = q.cols();
    debug_assert_eq!(k.shape(), (s, d));
    debug_assert_eq!(v.shape(), (s, d));
    debug_assert_eq!(d % heads, 0);
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    out.resize(s, d);

    match pool {
        Some(pool) if pool.size() > 1 && s * heads > 1 => {
            // Tile rows so each job amortizes its scratch; cap tiles at
            // ~2 per worker per head dimension for load balance on the
            // triangular (row-length-proportional) work distribution.
            let chunk = (s / (pool.size() * 2)).max(4).min(s);
            let chunks = s.div_ceil(chunk);
            let jobs = heads * chunks;
            let recomputed = AtomicUsize::new(0);
            let tiles = AtomicUsize::new(0);
            let tiles_total = AtomicUsize::new(0);
            let tile_out = TileOut(out.data_mut().as_mut_ptr());
            pool.scope_run(jobs, |job| {
                let h = job / chunks;
                let c = job % chunks;
                let off = h * hd;
                let r0 = c * chunk;
                let r1 = (r0 + chunk).min(s);
                let mut scores: Vec<f32> = Vec::with_capacity(r1);
                let mut rec = RowLamp::default();
                for i in r0..r1 {
                    let qi = &q.row(i)[off..off + hd];
                    // SAFETY: (i, off) slices are disjoint across jobs —
                    // each job owns its head's columns of its rows — and
                    // scope_run joins every job before returning, so the
                    // pointer outlives all writes.
                    let orow = unsafe {
                        std::slice::from_raw_parts_mut(tile_out.0.add(i * d + off), hd)
                    };
                    rec.merge(lamp_attention_row(
                        qi,
                        k,
                        v,
                        off,
                        i + 1,
                        scale,
                        prec,
                        row_stream_seed(seed, h, i),
                        &mut scores,
                        orow,
                    ));
                }
                recomputed.fetch_add(rec.recomputed, Ordering::Relaxed);
                tiles.fetch_add(rec.tiles, Ordering::Relaxed);
                tiles_total.fetch_add(rec.tiles_total, Ordering::Relaxed);
            });
            RowLamp {
                recomputed: recomputed.load(Ordering::Relaxed),
                tiles: tiles.load(Ordering::Relaxed),
                tiles_total: tiles_total.load(Ordering::Relaxed),
            }
        }
        _ => {
            let mut scores: Vec<f32> = Vec::with_capacity(s);
            let mut acc = RowLamp::default();
            for h in 0..heads {
                let off = h * hd;
                for i in 0..s {
                    let qi = &q.row(i)[off..off + hd];
                    // Split the mutable output row slice out via index
                    // arithmetic identical to the parallel path.
                    let orow = &mut out.row_mut(i)[off..off + hd];
                    acc.merge(lamp_attention_row(
                        qi,
                        k,
                        v,
                        off,
                        i + 1,
                        scale,
                        prec,
                        row_stream_seed(seed, h, i),
                        &mut scores,
                        orow,
                    ));
                }
            }
            acc
        }
    }
}

/// Causal multi-head attention for one sequence (sequential, allocating).
///
/// * `q`, `k`, `v` — [S, d_model] post-projection activations.
/// * `seed` — stream id for the `Random` rule; forked per (head, row).
/// * Returns the attention output [S, d_model]; adds the number of
///   recomputed KQ products to `recompute_count`.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    heads: usize,
    prec: AttentionPrecision,
    seed: u64,
    recompute_count: &mut usize,
) -> Matrix {
    let mut out = Matrix::zeros(q.rows(), q.cols());
    *recompute_count +=
        causal_attention_into(q, k, v, heads, prec, seed, None, &mut out).recomputed;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(s: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        (
            Matrix::randn(s, d, 1.0, &mut rng),
            Matrix::randn(s, d, 1.0, &mut rng),
            Matrix::randn(s, d, 1.0, &mut rng),
        )
    }

    #[test]
    fn reference_equals_uniform_mu23() {
        let (q, k, v) = setup(8, 16, 1);
        let mut n1 = 0;
        let a = causal_attention(&q, &k, &v, 2, AttentionPrecision::reference(), 0, &mut n1);
        let mut n2 = 0;
        let b = causal_attention(&q, &k, &v, 2, AttentionPrecision::uniform(23), 0, &mut n2);
        assert_eq!(a, b);
        assert_eq!(n1, 0);
        assert_eq!(n2, 0);
    }

    #[test]
    fn row_zero_attends_to_itself_only() {
        // Causal: position 0 can only see position 0 → output row 0 = v row 0.
        let (q, k, v) = setup(4, 8, 2);
        let mut n = 0;
        let out = causal_attention(&q, &k, &v, 2, AttentionPrecision::reference(), 0, &mut n);
        for c in 0..8 {
            assert!((out.get(0, c) - v.get(0, c)).abs() < 1e-6);
        }
    }

    #[test]
    fn low_precision_deviates_lamp_recovers() {
        let (q, k, v) = setup(16, 32, 3);
        let mut n = 0;
        let reference =
            causal_attention(&q, &k, &v, 4, AttentionPrecision::reference(), 0, &mut n);
        let mut n_uni = 0;
        let uniform =
            causal_attention(&q, &k, &v, 4, AttentionPrecision::uniform(3), 0, &mut n_uni);
        let mut n_lamp = 0;
        let lamp = causal_attention(
            &q,
            &k,
            &v,
            4,
            AttentionPrecision::lamp(3, 0.01, SoftmaxRule::Strict),
            0,
            &mut n_lamp,
        );
        assert_eq!(n_uni, 0);
        assert!(n_lamp > 0, "LAMP should recompute something at tau=0.01");
        let e_uni = uniform.max_abs_diff(&reference).unwrap();
        let e_lamp = lamp.max_abs_diff(&reference).unwrap();
        assert!(
            e_lamp < e_uni,
            "LAMP should beat uniform: lamp={e_lamp} uniform={e_uni}"
        );
    }

    #[test]
    fn recompute_all_recovers_reference_scores() {
        // tau = 0 with strict rule recomputes every nonzero-sensitivity
        // product; the result should be very close to the FP32 reference
        // (identical where all products are recomputed).
        let (q, k, v) = setup(12, 16, 4);
        let mut n = 0;
        let reference =
            causal_attention(&q, &k, &v, 2, AttentionPrecision::reference(), 0, &mut n);
        let mut n_all = 0;
        let lamp = causal_attention(
            &q,
            &k,
            &v,
            2,
            AttentionPrecision::lamp(2, 0.0, SoftmaxRule::Strict),
            0,
            &mut n_all,
        );
        let e = lamp.max_abs_diff(&reference).unwrap();
        assert!(e < 1e-5, "tau=0 should recover reference: {e}");
    }

    #[test]
    fn parallel_tiles_bit_identical_to_sequential_all_rules() {
        // The engine contract: pool-tiled attention reproduces the
        // sequential loop bit-for-bit, including the Random rule — every
        // (head, row) has its own RNG stream, so thread order is free.
        let pool = ThreadPool::new(4);
        let (q, k, v) = setup(33, 32, 7); // odd S exercises ragged tiles
        let rules = [
            SoftmaxRule::Strict,
            SoftmaxRule::Relaxed,
            SoftmaxRule::RelaxedLengthNorm { ref_len: 64 },
            SoftmaxRule::Random,
            SoftmaxRule::Tile { width: 8 },
            SoftmaxRule::TileRandom { width: 8 },
        ];
        for rule in rules {
            for prec in [
                AttentionPrecision::reference(),
                AttentionPrecision::uniform(4),
                AttentionPrecision::lamp(4, 0.05, rule),
            ] {
                let mut seq_out = Matrix::zeros(0, 0);
                let n_seq =
                    causal_attention_into(&q, &k, &v, 4, prec, 99, None, &mut seq_out);
                let seq = seq_out;
                let mut par = Matrix::zeros(0, 0);
                let n_par =
                    causal_attention_into(&q, &k, &v, 4, prec, 99, Some(&pool), &mut par);
                assert_eq!(n_seq, n_par, "{rule:?}: recompute counts diverge");
                assert_eq!(seq.shape(), par.shape());
                for r in 0..seq.rows() {
                    for c in 0..seq.cols() {
                        assert_eq!(
                            seq.get(r, c).to_bits(),
                            par.get(r, c).to_bits(),
                            "{rule:?}: ({r},{c})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn random_rule_is_head_order_independent() {
        // Two heads, same (q, k) content per head: with per-(head, row)
        // streams the masks differ across heads (independent draws), and
        // recomputing with the heads' data swapped swaps the outputs
        // exactly — no cross-head RNG coupling.
        let (q, k, v) = setup(10, 16, 11);
        let prec = AttentionPrecision::lamp(3, 0.05, SoftmaxRule::Random);
        let mut n1 = 0;
        let a = causal_attention(&q, &k, &v, 2, prec, 5, &mut n1);
        let mut n2 = 0;
        let b = causal_attention(&q, &k, &v, 2, prec, 5, &mut n2);
        assert_eq!(a, b, "same seed must reproduce exactly");
        assert_eq!(n1, n2);
        let mut n3 = 0;
        let c = causal_attention(&q, &k, &v, 2, prec, 6, &mut n3);
        assert!(
            a != c || n1 == 0,
            "different seeds should draw different random masks"
        );
    }

    #[test]
    fn stats_rate() {
        let mut s = LampStats {
            recomputed: 5,
            causal_total: 100,
            per_layer: vec![2, 3],
            ..LampStats::default()
        };
        assert!((s.rate() - 0.05).abs() < 1e-12);
        let other = LampStats {
            recomputed: 1,
            causal_total: 100,
            per_layer: vec![0, 1, 0],
            mlp: SiteStats { recomputed: 3, total: 10 },
            ..LampStats::default()
        };
        s.merge(&other);
        assert_eq!(s.recomputed, 6);
        assert_eq!(s.causal_total, 200);
        assert_eq!(s.per_layer, vec![2, 4, 0]);
        assert_eq!(s.mlp, SiteStats { recomputed: 3, total: 10 });
        assert!((s.mlp.rate() - 0.3).abs() < 1e-12);
        assert_eq!(LampStats::default().rate(), 0.0);
        assert_eq!(SiteStats::default().rate(), 0.0);
        let rates = s.site_rates();
        assert_eq!(rates.len(), 5);
        assert_eq!(rates[0].0, "attention");
        assert_eq!(rates[1], ("mlp".to_string(), 0.3));
        assert_eq!(rates[4].0, "attention_tiles");
    }

    #[test]
    fn reference_detection() {
        assert!(AttentionPrecision::reference().is_reference());
        assert!(AttentionPrecision::uniform(23).is_reference());
        assert!(!AttentionPrecision::uniform(4).is_reference());
        assert!(!AttentionPrecision::lamp(23, 0.1, SoftmaxRule::Strict).is_reference());
        assert!(!AttentionPrecision::lamp(4, 0.1, SoftmaxRule::Strict).is_reference());
    }

    #[test]
    fn site_stats_scaled() {
        let s = SiteStats { recomputed: 10, total: 100 };
        assert_eq!(s.scaled(0.5), SiteStats { recomputed: 5, total: 50 });
        assert_eq!(s.scaled(1.0), s);
    }

    #[test]
    fn stats_add_row() {
        let mut s = LampStats::default();
        let row = |r, t, tt| RowLamp { recomputed: r, tiles: t, tiles_total: tt };
        s.add_row(1, 10, row(2, 1, 2));
        s.add_row(0, 4, row(0, 0, 0));
        s.add_row(1, 11, row(3, 2, 3));
        assert_eq!(s.causal_total, 25);
        assert_eq!(s.recomputed, 5);
        assert_eq!(s.per_layer, vec![0, 5]);
        assert_eq!(s.tiles, SiteStats { recomputed: 3, total: 5 });
    }

    #[test]
    fn tile_rule_accounts_tiles_and_recovers_accuracy() {
        let (q, k, v) = setup(24, 32, 21);
        let mut n = 0;
        let reference =
            causal_attention(&q, &k, &v, 4, AttentionPrecision::reference(), 0, &mut n);
        let mut uniform_out = Matrix::zeros(0, 0);
        causal_attention_into(
            &q,
            &k,
            &v,
            4,
            AttentionPrecision::uniform(3),
            0,
            None,
            &mut uniform_out,
        );
        let prec = AttentionPrecision::lamp(3, 0.01, SoftmaxRule::Tile { width: 4 });
        let mut tiled_out = Matrix::zeros(0, 0);
        let acc = causal_attention_into(&q, &k, &v, 4, prec, 0, None, &mut tiled_out);
        // Tile counters are populated and consistent with the recompute
        // count (each selected tile covers at most `width` products).
        assert!(acc.tiles_total > 0);
        assert!(acc.tiles > 0, "diagonal tiles are always selected");
        assert!(acc.tiles <= acc.tiles_total);
        assert!(acc.recomputed <= acc.tiles * 4);
        assert!(acc.recomputed >= acc.tiles, "each tile has >= 1 product");
        // And the repair actually recovers accuracy over uniform PS.
        let e_uni = uniform_out.max_abs_diff(&reference).unwrap();
        let e_tile = tiled_out.max_abs_diff(&reference).unwrap();
        assert!(
            e_tile < e_uni,
            "tile LAMP should beat uniform: tile={e_tile} uniform={e_uni}"
        );
    }
}
