//! Causal multi-head attention with LAMP mixed-precision KQ accumulation —
//! the paper's §4.2 experimental setting, instrumented.
//!
//! Per head and per query row i:
//! 1. Accumulate the causal KQ inner products y_j = ⟨q_i, k_j⟩ (j ≤ i) in
//!    PS(μ) with per-step rounding, then scale by 1/√d_h in FP32.
//! 2. Apply the LAMP selection rule to the scaled row.
//! 3. Recompute the flagged inner products in FP32 (and rescale).
//! 4. FP32 softmax over the row; FP32 value aggregation.
//!
//! `AttentionPrecision::reference()` (μ=23) reproduces uniform FP32
//! accumulation bit-for-bit; `tau = ∞` reproduces uniform PS(μ).

use crate::lamp::softmax::{select_softmax, softmax, SoftmaxRule};
use crate::linalg::Matrix;
use crate::softfloat::dot::{dot_f32, dot_ps};
use crate::util::Rng;

/// Precision policy for attention score computation.
#[derive(Debug, Clone, Copy)]
pub struct AttentionPrecision {
    /// Mantissa bits for KQ accumulation (23 = FP32).
    pub mu: u32,
    /// LAMP threshold; `f32::INFINITY` disables recomputation.
    pub tau: f32,
    /// Selection rule.
    pub rule: SoftmaxRule,
}

impl AttentionPrecision {
    /// Uniform FP32 accumulation (the paper's reference model).
    pub fn reference() -> Self {
        AttentionPrecision { mu: 23, tau: f32::INFINITY, rule: SoftmaxRule::Strict }
    }

    /// Uniform PS(μ) accumulation, no recomputation.
    pub fn uniform(mu: u32) -> Self {
        AttentionPrecision { mu, tau: f32::INFINITY, rule: SoftmaxRule::Strict }
    }

    /// LAMP with the given rule.
    pub fn lamp(mu: u32, tau: f32, rule: SoftmaxRule) -> Self {
        AttentionPrecision { mu, tau, rule }
    }
}

/// Recomputation statistics accumulated over a forward pass.
#[derive(Debug, Clone, Default)]
pub struct LampStats {
    /// KQ inner products recomputed in FP32.
    pub recomputed: usize,
    /// Total KQ inner products in the causal mask.
    pub causal_total: usize,
    /// Per-layer recomputation counts.
    pub per_layer: Vec<usize>,
}

impl LampStats {
    /// Recomputation rate = recomputed / causal_total.
    pub fn rate(&self) -> f64 {
        if self.causal_total == 0 {
            0.0
        } else {
            self.recomputed as f64 / self.causal_total as f64
        }
    }

    /// Merge another pass's statistics (layer-wise aligned).
    pub fn merge(&mut self, other: &LampStats) {
        self.recomputed += other.recomputed;
        self.causal_total += other.causal_total;
        if self.per_layer.len() < other.per_layer.len() {
            self.per_layer.resize(other.per_layer.len(), 0);
        }
        for (i, &c) in other.per_layer.iter().enumerate() {
            self.per_layer[i] += c;
        }
    }
}

/// Causal multi-head attention for one sequence.
///
/// * `q`, `k`, `v` — [S, d_model] post-projection activations.
/// * Returns the attention output [S, d_model] and the number of
///   recomputed KQ products.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    heads: usize,
    prec: AttentionPrecision,
    rng: &mut Rng,
    recompute_count: &mut usize,
) -> Matrix {
    let s = q.rows();
    let d = q.cols();
    debug_assert_eq!(k.shape(), (s, d));
    debug_assert_eq!(v.shape(), (s, d));
    debug_assert_eq!(d % heads, 0);
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Matrix::zeros(s, d);

    let mut scores: Vec<f32> = Vec::with_capacity(s);
    for h in 0..heads {
        let off = h * hd;
        for i in 0..s {
            let qi = &q.row(i)[off..off + hd];
            // Step 1: PS(μ) accumulation of the causal row, FP32 scaling.
            scores.clear();
            for j in 0..=i {
                let kj = &k.row(j)[off..off + hd];
                scores.push(dot_ps(qi, kj, prec.mu) * scale);
            }
            // Steps 2–3: LAMP selection + FP32 recomputation.
            if prec.tau.is_finite() {
                let mask = select_softmax(&scores, prec.tau, prec.rule, rng);
                for (j, &m) in mask.iter().enumerate() {
                    if m {
                        let kj = &k.row(j)[off..off + hd];
                        scores[j] = dot_f32(qi, kj) * scale;
                        *recompute_count += 1;
                    }
                }
            }
            // Step 4: FP32 softmax + value aggregation.
            let probs = softmax(&scores);
            let orow = &mut out.row_mut(i)[off..off + hd];
            for (j, &p) in probs.iter().enumerate() {
                let vj = &v.row(j)[off..off + hd];
                for (o, &vv) in orow.iter_mut().zip(vj) {
                    *o += p * vv;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(s: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        (
            Matrix::randn(s, d, 1.0, &mut rng),
            Matrix::randn(s, d, 1.0, &mut rng),
            Matrix::randn(s, d, 1.0, &mut rng),
        )
    }

    #[test]
    fn reference_equals_uniform_mu23() {
        let (q, k, v) = setup(8, 16, 1);
        let mut rng = Rng::new(0);
        let mut n1 = 0;
        let a = causal_attention(&q, &k, &v, 2, AttentionPrecision::reference(), &mut rng, &mut n1);
        let mut n2 = 0;
        let b = causal_attention(&q, &k, &v, 2, AttentionPrecision::uniform(23), &mut rng, &mut n2);
        assert_eq!(a, b);
        assert_eq!(n1, 0);
        assert_eq!(n2, 0);
    }

    #[test]
    fn row_zero_attends_to_itself_only() {
        // Causal: position 0 can only see position 0 → output row 0 = v row 0.
        let (q, k, v) = setup(4, 8, 2);
        let mut rng = Rng::new(0);
        let mut n = 0;
        let out = causal_attention(&q, &k, &v, 2, AttentionPrecision::reference(), &mut rng, &mut n);
        for c in 0..8 {
            assert!((out.get(0, c) - v.get(0, c)).abs() < 1e-6);
        }
    }

    #[test]
    fn low_precision_deviates_lamp_recovers() {
        let (q, k, v) = setup(16, 32, 3);
        let mut rng = Rng::new(0);
        let mut n = 0;
        let reference =
            causal_attention(&q, &k, &v, 4, AttentionPrecision::reference(), &mut rng, &mut n);
        let mut n_uni = 0;
        let uniform =
            causal_attention(&q, &k, &v, 4, AttentionPrecision::uniform(3), &mut rng, &mut n_uni);
        let mut n_lamp = 0;
        let lamp = causal_attention(
            &q,
            &k,
            &v,
            4,
            AttentionPrecision::lamp(3, 0.01, SoftmaxRule::Strict),
            &mut rng,
            &mut n_lamp,
        );
        assert_eq!(n_uni, 0);
        assert!(n_lamp > 0, "LAMP should recompute something at tau=0.01");
        let e_uni = uniform.max_abs_diff(&reference).unwrap();
        let e_lamp = lamp.max_abs_diff(&reference).unwrap();
        assert!(
            e_lamp < e_uni,
            "LAMP should beat uniform: lamp={e_lamp} uniform={e_uni}"
        );
    }

    #[test]
    fn recompute_all_recovers_reference_scores() {
        // tau = 0 with strict rule recomputes every nonzero-sensitivity
        // product; the result should be very close to the FP32 reference
        // (identical where all products are recomputed).
        let (q, k, v) = setup(12, 16, 4);
        let mut rng = Rng::new(0);
        let mut n = 0;
        let reference =
            causal_attention(&q, &k, &v, 2, AttentionPrecision::reference(), &mut rng, &mut n);
        let mut n_all = 0;
        let lamp = causal_attention(
            &q,
            &k,
            &v,
            2,
            AttentionPrecision::lamp(2, 0.0, SoftmaxRule::Strict),
            &mut rng,
            &mut n_all,
        );
        let e = lamp.max_abs_diff(&reference).unwrap();
        assert!(e < 1e-5, "tau=0 should recover reference: {e}");
    }

    #[test]
    fn stats_rate() {
        let mut s = LampStats { recomputed: 5, causal_total: 100, per_layer: vec![2, 3] };
        assert!((s.rate() - 0.05).abs() < 1e-12);
        let other = LampStats { recomputed: 1, causal_total: 100, per_layer: vec![0, 1, 0] };
        s.merge(&other);
        assert_eq!(s.recomputed, 6);
        assert_eq!(s.causal_total, 200);
        assert_eq!(s.per_layer, vec![2, 4, 0]);
        assert_eq!(LampStats::default().rate(), 0.0);
    }
}
