//! Next-token cross-entropy / perplexity over logits (App. C.5 metric).

use crate::linalg::Matrix;

/// Per-token next-token negative log-likelihoods (natural log).
///
/// `logits` is [S, V]; position i predicts `tokens[i+1]`, so S−1 values are
/// returned. Uses the log-sum-exp trick in f64.
pub fn next_token_nll(logits: &Matrix, tokens: &[u32]) -> Vec<f64> {
    let s = logits.rows();
    assert_eq!(s, tokens.len());
    let mut out = Vec::with_capacity(s.saturating_sub(1));
    for i in 0..s.saturating_sub(1) {
        let row = logits.row(i);
        let target = tokens[i + 1] as usize;
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lse: f64 = row.iter().map(|&v| ((v as f64) - m).exp()).sum::<f64>().ln() + m;
        out.push(lse - row[target] as f64);
    }
    out
}

/// Perplexity = exp(mean NLL) over a stream of per-token NLLs.
pub fn perplexity(nlls: &[f64]) -> f64 {
    if nlls.is_empty() {
        return f64::NAN;
    }
    (nlls.iter().sum::<f64>() / nlls.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_vocab_perplexity() {
        // All-equal logits → uniform distribution → PPL = V.
        let v = 16;
        let s = 8;
        let logits = Matrix::zeros(s, v);
        let tokens: Vec<u32> = (0..s as u32).map(|i| i % v as u32).collect();
        let nll = next_token_nll(&logits, &tokens);
        assert_eq!(nll.len(), s - 1);
        let ppl = perplexity(&nll);
        assert!((ppl - v as f64).abs() < 1e-9, "ppl={ppl}");
    }

    #[test]
    fn confident_correct_prediction_low_nll() {
        let mut logits = Matrix::zeros(2, 4);
        logits.set(0, 2, 20.0); // predicts token 2 strongly
        let tokens = vec![0u32, 2u32];
        let nll = next_token_nll(&logits, &tokens);
        assert!(nll[0] < 1e-6, "nll={}", nll[0]);
    }

    #[test]
    fn confident_wrong_prediction_high_nll() {
        let mut logits = Matrix::zeros(2, 4);
        logits.set(0, 1, 20.0); // predicts token 1
        let tokens = vec![0u32, 2u32]; // actual next is 2
        let nll = next_token_nll(&logits, &tokens);
        assert!(nll[0] > 10.0, "nll={}", nll[0]);
    }

    #[test]
    fn empty_stream() {
        assert!(perplexity(&[]).is_nan());
        let logits = Matrix::zeros(1, 4);
        assert!(next_token_nll(&logits, &[0]).is_empty());
    }
}
