//! Model parameters over mixed-precision [`WeightTensor`] storage:
//! loading from `.lamp` tensor files (produced by the Python compile
//! path), random initialization (for tests and the untrained baseline),
//! and [`Weights::quantize_to`] storage conversion.
//!
//! Weight *matrices* (embeddings, QKV/proj, MLP fc/out) carry the storage
//! format; biases and layernorm gains stay `Vec<f32>` — they are O(d)
//! against the matrices' O(d²), always added in f32, and precision-
//! critical, so quantizing them buys no bandwidth and costs accuracy.
//! F32 storage reproduces the historical `Matrix`-backed weights bit for
//! bit (`rust/tests/plan_parity.rs` pins this).

use super::config::ModelConfig;
use crate::error::{Error, Result};
use crate::linalg::{Matrix, WeightFormat, WeightStore, WeightTensor};
use crate::tensorio::{DType, Tensor, TensorFile};
use crate::util::Rng;
use std::path::Path;

/// One transformer block's parameters.
#[derive(Debug, Clone)]
pub struct BlockWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    /// [d_model, 3·d_model] — fused QKV projection.
    pub w_qkv: WeightTensor,
    pub b_qkv: Vec<f32>,
    /// [d_model, d_model] — attention output projection.
    pub w_proj: WeightTensor,
    pub b_proj: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    /// [d_model, d_ff].
    pub w_fc: WeightTensor,
    pub b_fc: Vec<f32>,
    /// [d_ff, d_model].
    pub w_out: WeightTensor,
    pub b_out: Vec<f32>,
}

/// Full model parameters (embeddings tied to the output head).
#[derive(Debug, Clone)]
pub struct Weights {
    pub config: ModelConfig,
    /// Token embeddings [vocab, d_model].
    pub wte: WeightTensor,
    /// Positional embeddings [seq, d_model].
    pub wpe: WeightTensor,
    pub blocks: Vec<BlockWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
}

impl Weights {
    /// GPT-2-style random initialization (N(0, 0.02), residual projections
    /// scaled by 1/√(2L)), stored in f32. Invalid configs are rejected as
    /// a typed error, like the tensor-file loaders.
    pub fn random(config: &ModelConfig, rng: &mut Rng) -> Result<Self> {
        config.validate()?;
        let d = config.d_model;
        let resid_scale = 1.0 / ((2 * config.layers) as f32).sqrt();
        let blocks = (0..config.layers)
            .map(|l| {
                let mut r = rng.fork(l as u64 + 1);
                BlockWeights {
                    ln1_g: vec![1.0; d],
                    ln1_b: vec![0.0; d],
                    w_qkv: Matrix::randn(d, 3 * d, 0.02, &mut r).into(),
                    b_qkv: vec![0.0; 3 * d],
                    w_proj: Matrix::randn(d, d, 0.02 * resid_scale, &mut r).into(),
                    b_proj: vec![0.0; d],
                    ln2_g: vec![1.0; d],
                    ln2_b: vec![0.0; d],
                    w_fc: Matrix::randn(d, config.d_ff(), 0.02, &mut r).into(),
                    b_fc: vec![0.0; config.d_ff()],
                    w_out: Matrix::randn(config.d_ff(), d, 0.02 * resid_scale, &mut r)
                        .into(),
                    b_out: vec![0.0; d],
                }
            })
            .collect();
        Ok(Weights {
            config: config.clone(),
            wte: Matrix::randn(config.vocab, d, 0.02, rng).into(),
            wpe: Matrix::randn(config.seq, d, 0.01, rng).into(),
            blocks,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
        })
    }

    /// Re-store every weight matrix under `fmt` (biases/layernorm params
    /// stay f32). `quantize_to(WeightFormat::F32)` on f32-storage weights
    /// is the identity; on quantized weights it is the exact
    /// dequantization (every stored value is an exact f32). Same-format
    /// conversion is a single clone (quantization is idempotent, so the
    /// re-round could never change anything).
    pub fn quantize_to(&self, fmt: WeightFormat) -> Result<Self> {
        fmt.validate()?;
        if fmt == self.weight_format() {
            return Ok(self.clone());
        }
        let mut out = self.clone();
        out.wte = out.wte.quantize_to(fmt)?;
        out.wpe = out.wpe.quantize_to(fmt)?;
        for b in &mut out.blocks {
            b.w_qkv = b.w_qkv.quantize_to(fmt)?;
            b.w_proj = b.w_proj.quantize_to(fmt)?;
            b.w_fc = b.w_fc.quantize_to(fmt)?;
            b.w_out = b.w_out.quantize_to(fmt)?;
        }
        Ok(out)
    }

    /// The storage format of the weight matrices. `quantize_to` and the
    /// loaders keep it uniform across tensors; the embedding table is the
    /// representative.
    pub fn weight_format(&self) -> WeightFormat {
        self.wte.format()
    }

    /// Resident parameter bytes: quantized matrix payloads at their stored
    /// width plus the f32 bias/layernorm vectors — the number the decode
    /// path actually streams per full pass.
    pub fn resident_param_bytes(&self) -> usize {
        let vecs = |v: &Vec<f32>| 4 * v.len();
        let mut total = self.wte.resident_bytes() + self.wpe.resident_bytes();
        total += vecs(&self.lnf_g) + vecs(&self.lnf_b);
        for b in &self.blocks {
            total += b.w_qkv.resident_bytes()
                + b.w_proj.resident_bytes()
                + b.w_fc.resident_bytes()
                + b.w_out.resident_bytes();
            total += vecs(&b.ln1_g)
                + vecs(&b.ln1_b)
                + vecs(&b.b_qkv)
                + vecs(&b.b_proj)
                + vecs(&b.ln2_g)
                + vecs(&b.ln2_b)
                + vecs(&b.b_fc)
                + vecs(&b.b_out);
        }
        total
    }

    /// Load from a `.lamp` tensor file using the canonical naming scheme
    /// (`wte`, `wpe`, `h{i}.ln1.g`, ..., `lnf.b`) written by
    /// `python/compile/tensorio.py`.
    pub fn load(path: impl AsRef<Path>, config: &ModelConfig) -> Result<Self> {
        let file = TensorFile::load(path)?;
        Self::from_tensor_file(&file, config)
    }

    /// Build from an in-memory [`TensorFile`]. Weight matrices adopt the
    /// dtype each tensor was stored with (f32 / bf16 / ps-f32).
    pub fn from_tensor_file(file: &TensorFile, config: &ModelConfig) -> Result<Self> {
        config.validate()?;
        let d = config.d_model;
        let mat = |name: &str, rows: usize, cols: usize| -> Result<WeightTensor> {
            let t = file.require(name)?;
            if t.dims != vec![rows, cols] {
                return Err(Error::shape(format!(
                    "{name}: expected [{rows}, {cols}], got {:?}",
                    t.dims
                )));
            }
            match t.dtype {
                DType::F32 => WeightTensor::from_f32(rows, cols, t.as_f32()?),
                DType::Bf16 => WeightTensor::from_bf16(rows, cols, t.as_bf16()?),
                DType::PsF32 { mu } => {
                    WeightTensor::from_ps(rows, cols, mu, t.dequant_f32()?)
                }
                DType::I32 => Err(Error::format(format!(
                    "{name}: i32 is not a weight-matrix dtype"
                ))),
            }
        };
        let vec1 = |name: &str, len: usize| -> Result<Vec<f32>> {
            let t = file.require(name)?;
            if t.dims != vec![len] {
                return Err(Error::shape(format!(
                    "{name}: expected [{len}], got {:?}",
                    t.dims
                )));
            }
            t.as_f32()
        };
        let mut blocks = Vec::with_capacity(config.layers);
        for l in 0..config.layers {
            let p = |s: &str| format!("h{l}.{s}");
            blocks.push(BlockWeights {
                ln1_g: vec1(&p("ln1.g"), d)?,
                ln1_b: vec1(&p("ln1.b"), d)?,
                w_qkv: mat(&p("attn.w_qkv"), d, 3 * d)?,
                b_qkv: vec1(&p("attn.b_qkv"), 3 * d)?,
                w_proj: mat(&p("attn.w_proj"), d, d)?,
                b_proj: vec1(&p("attn.b_proj"), d)?,
                ln2_g: vec1(&p("ln2.g"), d)?,
                ln2_b: vec1(&p("ln2.b"), d)?,
                w_fc: mat(&p("mlp.w_fc"), d, config.d_ff())?,
                b_fc: vec1(&p("mlp.b_fc"), config.d_ff())?,
                w_out: mat(&p("mlp.w_out"), config.d_ff(), d)?,
                b_out: vec1(&p("mlp.b_out"), d)?,
            });
        }
        let w = Weights {
            config: config.clone(),
            wte: mat("wte", config.vocab, d)?,
            wpe: mat("wpe", config.seq, d)?,
            blocks,
            lnf_g: vec1("lnf.g", d)?,
            lnf_b: vec1("lnf.b", d)?,
        };
        // Enforce the uniform-storage invariant `weight_format()` reports
        // and the engine storage gate relies on: a file mixing matrix
        // dtypes would otherwise serve (and attribute stats for) a format
        // other than the declared one.
        let fmt = w.weight_format();
        let mut tensors: Vec<(&str, WeightFormat)> =
            vec![("wte", w.wte.format()), ("wpe", w.wpe.format())];
        for b in &w.blocks {
            tensors.push(("attn.w_qkv", b.w_qkv.format()));
            tensors.push(("attn.w_proj", b.w_proj.format()));
            tensors.push(("mlp.w_fc", b.w_fc.format()));
            tensors.push(("mlp.w_out", b.w_out.format()));
        }
        if let Some((name, other)) = tensors.iter().find(|(_, f)| *f != fmt) {
            return Err(Error::format(format!(
                "mixed weight-storage dtypes: {name} is {}, wte is {} \
                 (quantize uniformly before writing the tensor file)",
                other.label(),
                fmt.label()
            )));
        }
        Ok(w)
    }

    /// Serialize into a [`TensorFile`] (inverse of [`Self::from_tensor_file`]).
    /// Each weight matrix is written in its storage dtype; f32-storage
    /// weights produce a byte-identical v1 file, quantized storage bumps
    /// the container to v2.
    pub fn to_tensor_file(&self) -> Result<TensorFile> {
        let wt = |name: String, w: &WeightTensor| -> Result<Tensor> {
            let dims = vec![w.rows(), w.cols()];
            match w.store() {
                WeightStore::F32(d) => Tensor::f32(name, dims, d),
                WeightStore::Bf16(d) => Tensor::bf16(name, dims, d),
                WeightStore::PsRounded { mu, data } => {
                    Tensor::ps_f32(name, dims, *mu, data)
                }
            }
        };
        let mut f = TensorFile::new();
        let c = &self.config;
        f.push(wt("wte".to_string(), &self.wte)?)?;
        f.push(wt("wpe".to_string(), &self.wpe)?)?;
        for (l, b) in self.blocks.iter().enumerate() {
            let p = |s: &str| format!("h{l}.{s}");
            f.push(Tensor::f32(p("ln1.g"), vec![c.d_model], &b.ln1_g)?)?;
            f.push(Tensor::f32(p("ln1.b"), vec![c.d_model], &b.ln1_b)?)?;
            f.push(wt(p("attn.w_qkv"), &b.w_qkv)?)?;
            f.push(Tensor::f32(p("attn.b_qkv"), vec![3 * c.d_model], &b.b_qkv)?)?;
            f.push(wt(p("attn.w_proj"), &b.w_proj)?)?;
            f.push(Tensor::f32(p("attn.b_proj"), vec![c.d_model], &b.b_proj)?)?;
            f.push(Tensor::f32(p("ln2.g"), vec![c.d_model], &b.ln2_g)?)?;
            f.push(Tensor::f32(p("ln2.b"), vec![c.d_model], &b.ln2_b)?)?;
            f.push(wt(p("mlp.w_fc"), &b.w_fc)?)?;
            f.push(Tensor::f32(p("mlp.b_fc"), vec![c.d_ff()], &b.b_fc)?)?;
            f.push(wt(p("mlp.w_out"), &b.w_out)?)?;
            f.push(Tensor::f32(p("mlp.b_out"), vec![c.d_model], &b.b_out)?)?;
        }
        f.push(Tensor::f32("lnf.g", vec![c.d_model], &self.lnf_g)?)?;
        f.push(Tensor::f32("lnf.b", vec![c.d_model], &self.lnf_b)?)?;
        Ok(f)
    }

    /// The canonical artifact input order: the flat list of weight tensors
    /// fed to the compiled HLO executable *after* (tokens, mu, tau, seed).
    /// The artifact consumes f32 buffers, so quantized storage is
    /// dequantized here (exact — every stored value is an exact f32).
    /// Must match `python/compile/model.py::weight_order`.
    pub fn artifact_order(&self) -> Vec<(&'static str, Vec<f32>)> {
        let mut out: Vec<(&'static str, Vec<f32>)> = Vec::new();
        out.push(("wte", self.wte.to_f32_vec()));
        out.push(("wpe", self.wpe.to_f32_vec()));
        for b in &self.blocks {
            out.push(("ln1.g", b.ln1_g.clone()));
            out.push(("ln1.b", b.ln1_b.clone()));
            out.push(("w_qkv", b.w_qkv.to_f32_vec()));
            out.push(("b_qkv", b.b_qkv.clone()));
            out.push(("w_proj", b.w_proj.to_f32_vec()));
            out.push(("b_proj", b.b_proj.clone()));
            out.push(("ln2.g", b.ln2_g.clone()));
            out.push(("ln2.b", b.ln2_b.clone()));
            out.push(("w_fc", b.w_fc.to_f32_vec()));
            out.push(("b_fc", b.b_fc.clone()));
            out.push(("w_out", b.w_out.to_f32_vec()));
            out.push(("b_out", b.b_out.clone()));
        }
        out.push(("lnf.g", self.lnf_g.clone()));
        out.push(("lnf.b", self.lnf_b.clone()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_init_shapes_and_f32_storage() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(1);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        assert_eq!(w.blocks.len(), 2);
        assert_eq!(w.wte.shape(), (128, 32));
        assert_eq!(w.blocks[0].w_qkv.shape(), (32, 96));
        assert_eq!(w.blocks[0].w_fc.shape(), (32, 128));
        assert_eq!(w.weight_format(), WeightFormat::F32);
    }

    #[test]
    fn random_init_rejects_invalid_config() {
        // Satellite contract: a bad config is a typed error, not a panic.
        let mut cfg = ModelConfig::nano();
        cfg.heads = 5; // does not divide d_model
        let mut rng = Rng::new(1);
        assert!(Weights::random(&cfg, &mut rng).is_err());
    }

    #[test]
    fn tensor_file_roundtrip() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(2);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        let f = w.to_tensor_file().unwrap();
        assert_eq!(f.required_version(), 1, "f32 storage must stay v1");
        let w2 = Weights::from_tensor_file(&f, &cfg).unwrap();
        assert_eq!(w.wte, w2.wte);
        assert_eq!(w.blocks[1].w_out, w2.blocks[1].w_out);
        assert_eq!(w.lnf_g, w2.lnf_g);
    }

    #[test]
    fn quantized_roundtrip_preserves_storage_format() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(6);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        for fmt in [WeightFormat::Bf16, WeightFormat::PsRounded { mu: 8 }] {
            let q = w.quantize_to(fmt).unwrap();
            assert_eq!(q.weight_format(), fmt);
            let f = q.to_tensor_file().unwrap();
            assert_eq!(f.required_version(), 2);
            let bytes = f.to_bytes();
            let q2 = Weights::from_tensor_file(
                &TensorFile::from_bytes(&bytes).unwrap(),
                &cfg,
            )
            .unwrap();
            assert_eq!(q2.weight_format(), fmt);
            assert_eq!(q.wte, q2.wte, "{fmt:?} wte");
            assert_eq!(q.blocks[0].w_fc, q2.blocks[0].w_fc, "{fmt:?} w_fc");
            // Biases stay exact f32 under every storage format.
            assert_eq!(q.blocks[0].b_fc, w.blocks[0].b_fc);
            // Requantization is the identity.
            assert_eq!(q.quantize_to(fmt).unwrap().wte, q.wte);
        }
    }

    #[test]
    fn bf16_halves_matrix_resident_bytes() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(7);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        let f32_bytes = w.resident_param_bytes();
        let bf16_bytes = w.quantize_to(WeightFormat::Bf16).unwrap().resident_param_bytes();
        assert!(bf16_bytes < f32_bytes);
        // Matrices dominate the parameter count, so total bytes land near
        // the 2x matrix saving (vectors stay f32).
        let ratio = f32_bytes as f64 / bf16_bytes as f64;
        assert!(ratio > 1.8, "ratio={ratio}");
        // PS-rounded storage is a simulation: no byte saving.
        let ps_bytes = w
            .quantize_to(WeightFormat::PsRounded { mu: 8 })
            .unwrap()
            .resident_param_bytes();
        assert_eq!(ps_bytes, f32_bytes);
    }

    #[test]
    fn mixed_storage_dtypes_rejected_at_load() {
        // The uniform-storage invariant behind `weight_format()` and the
        // engine storage gate: a file quantizing only some matrices must
        // not load as if it were uniformly stored.
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(8);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        let good = w.to_tensor_file().unwrap();
        let mut mixed = TensorFile::new();
        for t in good.tensors() {
            if t.name == "h0.attn.w_qkv" {
                let bf: Vec<u16> = t
                    .as_f32()
                    .unwrap()
                    .iter()
                    .map(|&x| crate::linalg::tensor::f32_to_bf16(x))
                    .collect();
                mixed
                    .push(Tensor::bf16(t.name.clone(), t.dims.clone(), &bf).unwrap())
                    .unwrap();
            } else {
                mixed.push(t.clone()).unwrap();
            }
        }
        let err = Weights::from_tensor_file(&mixed, &cfg).unwrap_err().to_string();
        assert!(err.contains("mixed weight-storage"), "{err}");
    }

    #[test]
    fn missing_tensor_rejected() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(3);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        let f = w.to_tensor_file().unwrap();
        // Ask for a config with more layers than the file provides.
        let mut bigger = cfg.clone();
        bigger.layers = 3;
        assert!(Weights::from_tensor_file(&f, &bigger).is_err());
    }

    #[test]
    fn wrong_shape_rejected() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(4);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        let f = w.to_tensor_file().unwrap();
        let mut wider = cfg.clone();
        wider.d_model = 64;
        wider.heads = 2;
        assert!(Weights::from_tensor_file(&f, &wider).is_err());
    }

    #[test]
    fn artifact_order_layout_dequantizes() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(5);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        let order = w.artifact_order();
        // 2 (emb) + 12 per layer × 2 + 2 (final ln) = 28
        assert_eq!(order.len(), 28);
        assert_eq!(order[0].0, "wte");
        assert_eq!(order[2].0, "ln1.g");
        assert_eq!(order.last().unwrap().0, "lnf.b");
        // Quantized storage feeds the artifact its dequantized values.
        let q = w.quantize_to(WeightFormat::Bf16).unwrap();
        let qo = q.artifact_order();
        assert_eq!(qo[0].1, q.wte.to_f32_vec());
        assert_eq!(qo.len(), 28);
    }
}
