//! Model parameters: loading from `.lamp` tensor files (produced by the
//! Python compile path) and random initialization (for tests and the
//! untrained baseline).

use super::config::ModelConfig;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::tensorio::TensorFile;
use crate::util::Rng;
use std::path::Path;

/// One transformer block's parameters.
#[derive(Debug, Clone)]
pub struct BlockWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    /// [d_model, 3·d_model] — fused QKV projection.
    pub w_qkv: Matrix,
    pub b_qkv: Vec<f32>,
    /// [d_model, d_model] — attention output projection.
    pub w_proj: Matrix,
    pub b_proj: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    /// [d_model, d_ff].
    pub w_fc: Matrix,
    pub b_fc: Vec<f32>,
    /// [d_ff, d_model].
    pub w_out: Matrix,
    pub b_out: Vec<f32>,
}

/// Full model parameters (embeddings tied to the output head).
#[derive(Debug, Clone)]
pub struct Weights {
    pub config: ModelConfig,
    /// Token embeddings [vocab, d_model].
    pub wte: Matrix,
    /// Positional embeddings [seq, d_model].
    pub wpe: Matrix,
    pub blocks: Vec<BlockWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
}

impl Weights {
    /// GPT-2-style random initialization (N(0, 0.02), residual projections
    /// scaled by 1/√(2L)).
    pub fn random(config: &ModelConfig, rng: &mut Rng) -> Self {
        config.validate().expect("valid config");
        let d = config.d_model;
        let resid_scale = 1.0 / ((2 * config.layers) as f32).sqrt();
        let blocks = (0..config.layers)
            .map(|l| {
                let mut r = rng.fork(l as u64 + 1);
                BlockWeights {
                    ln1_g: vec![1.0; d],
                    ln1_b: vec![0.0; d],
                    w_qkv: Matrix::randn(d, 3 * d, 0.02, &mut r),
                    b_qkv: vec![0.0; 3 * d],
                    w_proj: Matrix::randn(d, d, 0.02 * resid_scale, &mut r),
                    b_proj: vec![0.0; d],
                    ln2_g: vec![1.0; d],
                    ln2_b: vec![0.0; d],
                    w_fc: Matrix::randn(d, config.d_ff(), 0.02, &mut r),
                    b_fc: vec![0.0; config.d_ff()],
                    w_out: Matrix::randn(config.d_ff(), d, 0.02 * resid_scale, &mut r),
                    b_out: vec![0.0; d],
                }
            })
            .collect();
        Weights {
            config: config.clone(),
            wte: Matrix::randn(config.vocab, d, 0.02, rng),
            wpe: Matrix::randn(config.seq, d, 0.01, rng),
            blocks,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
        }
    }

    /// Load from a `.lamp` tensor file using the canonical naming scheme
    /// (`wte`, `wpe`, `h{i}.ln1.g`, ..., `lnf.b`) written by
    /// `python/compile/tensorio.py`.
    pub fn load(path: impl AsRef<Path>, config: &ModelConfig) -> Result<Self> {
        let file = TensorFile::load(path)?;
        Self::from_tensor_file(&file, config)
    }

    /// Build from an in-memory [`TensorFile`].
    pub fn from_tensor_file(file: &TensorFile, config: &ModelConfig) -> Result<Self> {
        config.validate()?;
        let d = config.d_model;
        let mat = |name: &str, rows: usize, cols: usize| -> Result<Matrix> {
            let t = file.require(name)?;
            if t.dims != vec![rows, cols] {
                return Err(Error::shape(format!(
                    "{name}: expected [{rows}, {cols}], got {:?}",
                    t.dims
                )));
            }
            Matrix::from_vec(rows, cols, t.as_f32()?)
        };
        let vec1 = |name: &str, len: usize| -> Result<Vec<f32>> {
            let t = file.require(name)?;
            if t.dims != vec![len] {
                return Err(Error::shape(format!(
                    "{name}: expected [{len}], got {:?}",
                    t.dims
                )));
            }
            t.as_f32()
        };
        let mut blocks = Vec::with_capacity(config.layers);
        for l in 0..config.layers {
            let p = |s: &str| format!("h{l}.{s}");
            blocks.push(BlockWeights {
                ln1_g: vec1(&p("ln1.g"), d)?,
                ln1_b: vec1(&p("ln1.b"), d)?,
                w_qkv: mat(&p("attn.w_qkv"), d, 3 * d)?,
                b_qkv: vec1(&p("attn.b_qkv"), 3 * d)?,
                w_proj: mat(&p("attn.w_proj"), d, d)?,
                b_proj: vec1(&p("attn.b_proj"), d)?,
                ln2_g: vec1(&p("ln2.g"), d)?,
                ln2_b: vec1(&p("ln2.b"), d)?,
                w_fc: mat(&p("mlp.w_fc"), d, config.d_ff())?,
                b_fc: vec1(&p("mlp.b_fc"), config.d_ff())?,
                w_out: mat(&p("mlp.w_out"), config.d_ff(), d)?,
                b_out: vec1(&p("mlp.b_out"), d)?,
            });
        }
        Ok(Weights {
            config: config.clone(),
            wte: mat("wte", config.vocab, d)?,
            wpe: mat("wpe", config.seq, d)?,
            blocks,
            lnf_g: vec1("lnf.g", d)?,
            lnf_b: vec1("lnf.b", d)?,
        })
    }

    /// Serialize into a [`TensorFile`] (inverse of [`Self::from_tensor_file`]).
    pub fn to_tensor_file(&self) -> Result<TensorFile> {
        use crate::tensorio::Tensor;
        let mut f = TensorFile::new();
        let c = &self.config;
        f.push(Tensor::f32("wte", vec![c.vocab, c.d_model], self.wte.data())?)?;
        f.push(Tensor::f32("wpe", vec![c.seq, c.d_model], self.wpe.data())?)?;
        for (l, b) in self.blocks.iter().enumerate() {
            let p = |s: &str| format!("h{l}.{s}");
            f.push(Tensor::f32(p("ln1.g"), vec![c.d_model], &b.ln1_g)?)?;
            f.push(Tensor::f32(p("ln1.b"), vec![c.d_model], &b.ln1_b)?)?;
            f.push(Tensor::f32(p("attn.w_qkv"), vec![c.d_model, 3 * c.d_model], b.w_qkv.data())?)?;
            f.push(Tensor::f32(p("attn.b_qkv"), vec![3 * c.d_model], &b.b_qkv)?)?;
            f.push(Tensor::f32(p("attn.w_proj"), vec![c.d_model, c.d_model], b.w_proj.data())?)?;
            f.push(Tensor::f32(p("attn.b_proj"), vec![c.d_model], &b.b_proj)?)?;
            f.push(Tensor::f32(p("ln2.g"), vec![c.d_model], &b.ln2_g)?)?;
            f.push(Tensor::f32(p("ln2.b"), vec![c.d_model], &b.ln2_b)?)?;
            f.push(Tensor::f32(p("mlp.w_fc"), vec![c.d_model, c.d_ff()], b.w_fc.data())?)?;
            f.push(Tensor::f32(p("mlp.b_fc"), vec![c.d_ff()], &b.b_fc)?)?;
            f.push(Tensor::f32(p("mlp.w_out"), vec![c.d_ff(), c.d_model], b.w_out.data())?)?;
            f.push(Tensor::f32(p("mlp.b_out"), vec![c.d_model], &b.b_out)?)?;
        }
        f.push(Tensor::f32("lnf.g", vec![c.d_model], &self.lnf_g)?)?;
        f.push(Tensor::f32("lnf.b", vec![c.d_model], &self.lnf_b)?)?;
        Ok(f)
    }

    /// The canonical artifact input order: the flat list of weight tensors
    /// fed to the compiled HLO executable *after* (tokens, mu, tau, seed).
    /// Must match `python/compile/model.py::weight_order`.
    pub fn artifact_order(&self) -> Vec<(&'static str, Vec<f32>)> {
        let mut out: Vec<(&'static str, Vec<f32>)> = Vec::new();
        out.push(("wte", self.wte.data().to_vec()));
        out.push(("wpe", self.wpe.data().to_vec()));
        for b in &self.blocks {
            out.push(("ln1.g", b.ln1_g.clone()));
            out.push(("ln1.b", b.ln1_b.clone()));
            out.push(("w_qkv", b.w_qkv.data().to_vec()));
            out.push(("b_qkv", b.b_qkv.clone()));
            out.push(("w_proj", b.w_proj.data().to_vec()));
            out.push(("b_proj", b.b_proj.clone()));
            out.push(("ln2.g", b.ln2_g.clone()));
            out.push(("ln2.b", b.ln2_b.clone()));
            out.push(("w_fc", b.w_fc.data().to_vec()));
            out.push(("b_fc", b.b_fc.clone()));
            out.push(("w_out", b.w_out.data().to_vec()));
            out.push(("b_out", b.b_out.clone()));
        }
        out.push(("lnf.g", self.lnf_g.clone()));
        out.push(("lnf.b", self.lnf_b.clone()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_init_shapes() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(1);
        let w = Weights::random(&cfg, &mut rng);
        assert_eq!(w.blocks.len(), 2);
        assert_eq!(w.wte.shape(), (128, 32));
        assert_eq!(w.blocks[0].w_qkv.shape(), (32, 96));
        assert_eq!(w.blocks[0].w_fc.shape(), (32, 128));
    }

    #[test]
    fn tensor_file_roundtrip() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(2);
        let w = Weights::random(&cfg, &mut rng);
        let f = w.to_tensor_file().unwrap();
        let w2 = Weights::from_tensor_file(&f, &cfg).unwrap();
        assert_eq!(w.wte, w2.wte);
        assert_eq!(w.blocks[1].w_out, w2.blocks[1].w_out);
        assert_eq!(w.lnf_g, w2.lnf_g);
    }

    #[test]
    fn missing_tensor_rejected() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(3);
        let w = Weights::random(&cfg, &mut rng);
        let f = w.to_tensor_file().unwrap();
        // Ask for a config with more layers than the file provides.
        let mut bigger = cfg.clone();
        bigger.layers = 3;
        assert!(Weights::from_tensor_file(&f, &bigger).is_err());
    }

    #[test]
    fn wrong_shape_rejected() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(4);
        let w = Weights::random(&cfg, &mut rng);
        let f = w.to_tensor_file().unwrap();
        let mut wider = cfg.clone();
        wider.d_model = 64;
        wider.heads = 2;
        assert!(Weights::from_tensor_file(&f, &wider).is_err());
    }

    #[test]
    fn artifact_order_layout() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(5);
        let w = Weights::random(&cfg, &mut rng);
        let order = w.artifact_order();
        // 2 (emb) + 12 per layer × 2 + 2 (final ln) = 28
        assert_eq!(order.len(), 28);
        assert_eq!(order[0].0, "wte");
        assert_eq!(order[2].0, "ln1.g");
        assert_eq!(order.last().unwrap().0, "lnf.b");
    }
}
