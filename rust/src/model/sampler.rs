//! Autoregressive sampling on top of the native engine — the serving-side
//! feature that turns the forward pass into text generation, used by the
//! `lamp serve`/examples to demonstrate LAMP under decode workloads.
//!
//! [`generate`] decodes through a [`DecodeSession`] KV cache: O(S) new KQ
//! inner products per token instead of a full O(S²) re-forward (see
//! DESIGN.md §Perf). [`generate_reforward`] keeps the original
//! re-run-everything loop as the benchmark baseline and parity oracle —
//! under every precision policy the two produce identical token streams,
//! because per-row attention state depends only on the row's position
//! (DESIGN.md §Bit-exactness).

use super::attention::LampStats;
use super::forward::forward;
use super::kvcache::DecodeSession;
use super::plan::PrecisionPlan;
use super::weights::Weights;
use crate::error::{Error, Result};
use crate::util::Rng;

/// Decoding strategy.
#[derive(Debug, Clone, Copy)]
pub enum Decode {
    /// Argmax.
    Greedy,
    /// Top-k sampling at the given temperature.
    TopK { k: usize, temperature: f32 },
}

impl Decode {
    /// Sample the next token from a logits row, consuming draws from `rng`
    /// only for stochastic strategies. Public because the continuous-batching
    /// scheduler must reproduce [`generate`]'s sampling stream exactly: same
    /// strategy, same per-request RNG, same call order.
    pub fn pick(self, logits: &[f32], rng: &mut Rng) -> Result<u32> {
        let _t = crate::obs::timers::scoped(crate::obs::timers::Site::Sampler);
        match self {
            Decode::Greedy => Ok(crate::metrics::flip::argmax(logits) as u32),
            Decode::TopK { k, temperature } => sample_topk(logits, k, temperature, rng),
        }
    }
}

/// Drive a *fresh* [`DecodeSession`] through prompt prefill and
/// `new_tokens` sampled continuation steps — the one decode loop.
/// [`generate`]/[`generate_with_stats`], `NativeEngine::generate`, the
/// CLI, and the benches all ride on it, so the "bit-identical to solo
/// generate" contract has a single definition site; callers that need a
/// non-default session (a shared/quantized [`KvBlockPool`] via
/// `DecodeSession::with_pool`) construct it themselves and pass it here.
/// The sampling stream is `Rng::new(session.seed())`, exactly as the
/// continuous-batching scheduler reproduces it.
///
/// [`KvBlockPool`]: super::kvstore::KvBlockPool
pub fn generate_with_session(
    session: &mut DecodeSession,
    prompt: &[u32],
    new_tokens: usize,
    decode: Decode,
) -> Result<(Vec<u32>, LampStats)> {
    if prompt.is_empty() {
        return Err(Error::shape("empty prompt".to_string()));
    }
    if !session.is_empty() {
        return Err(Error::invariant(
            "generate_with_session needs a fresh session".to_string(),
        ));
    }
    let cfg = session.config();
    let seq = cfg.seq;
    let mut tokens = prompt.to_vec();
    if tokens.len() >= seq || new_tokens == 0 {
        return Ok((tokens, LampStats::default()));
    }
    let mut rng = Rng::new(session.seed());
    session.prefill(prompt)?;
    if let Some(spec) = session.plan().spec {
        let draft_plan = session
            .plan()
            .draft_plan()
            .expect("plan with spec always yields a draft plan");
        speculative_loop(session, &mut tokens, new_tokens, decode, &mut rng, draft_plan, spec.k)?;
    } else {
        for _ in 0..new_tokens {
            let next = decode.pick(session.logits(), &mut rng)?;
            tokens.push(next);
            if tokens.len() >= seq {
                break;
            }
            session.decode_step(next)?;
        }
    }
    let stats = session.stats().clone();
    Ok((tokens, stats))
}

/// The draft/verify rounds of [`generate_with_session`] when the
/// session's plan carries a [`SpecConfig`](super::plan::SpecConfig) —
/// DESIGN.md §Speculative decoding.
///
/// Bit-exactness with the solo loop above is by construction: every
/// emitted token is picked from *target-plan* logits for its position
/// (solo's `session.logits()` after feeding ≡ the verify chunk's row for
/// the same position, which the KV-decode parity suite pins), with the
/// same `rng` in the same order. Draft steps approximate those logits
/// under the cheap plan against a scratch KV extension and consume only a
/// *clone* of the RNG stream; the round then rolls the scratch state back
/// and re-realizes the accepted prefix under the target plan's KV format
/// and repair, so committed state never depends on the draft plan.
fn speculative_loop(
    session: &mut DecodeSession,
    tokens: &mut Vec<u32>,
    new_tokens: usize,
    decode: Decode,
    rng: &mut Rng,
    draft_plan: PrecisionPlan,
    k: usize,
) -> Result<()> {
    let seq = session.config().seq;
    let mut next = decode.pick(session.logits(), rng)?;
    tokens.push(next);
    let mut emitted = 1usize;
    loop {
        if emitted == new_tokens {
            // Solo's final iteration feeds the last emitted token unless
            // the context is full — reproduce both the state and stats.
            if tokens.len() < seq {
                session.decode_step(next)?;
            }
            return Ok(());
        }
        if tokens.len() >= seq {
            return Ok(());
        }
        let n = session.len();
        // Candidates this round: the unfed base token plus up to k
        // drafts, bounded by the emission budget and the context window
        // (emission stops at tokens.len() == seq exactly as solo does,
        // which also keeps every fed position below seq).
        let m = (1 + k).min(new_tokens - emitted).min(seq - n - 1);
        if m >= 2 {
            // --- Draft: scratch KV extension under the cheap plan. ---
            let cp = session.spec_checkpoint();
            let mut cands = Vec::with_capacity(m);
            cands.push(next);
            let mut draft_rng = rng.clone();
            session.begin_draft();
            while cands.len() < m {
                match session.draft_step(*cands.last().expect("nonempty"), draft_plan) {
                    Ok(()) => cands.push(decode.pick(session.logits(), &mut draft_rng)?),
                    // Draft work is disposable: any failure (typically
                    // pool pressure from the scratch extension) just
                    // shortens the round; rollback below releases every
                    // draft block either way.
                    Err(_) => break,
                }
            }
            session.rollback(&cp);
            if cands.len() >= 2 {
                // --- Verify: one batched target-plan forward. ---
                session.verify_chunk(&cands)?;
                // --- Acceptance walk, real RNG: keep picking while the
                // picked token matches the draft that was fed next. ---
                let mut round = Vec::with_capacity(cands.len());
                round.push(decode.pick(session.chunk_logits_row(0), rng)?);
                while round.len() < cands.len()
                    && *round.last().expect("nonempty") == cands[round.len()]
                {
                    let j = round.len();
                    round.push(decode.pick(session.chunk_logits_row(j), rng)?);
                }
                let accepted_rows = round.len();
                session.commit_round(&cands[..accepted_rows]);
                session
                    .spec_stats_mut()
                    .record_round(cands.len() - 1, accepted_rows - 1, round.len());
                next = *round.last().expect("nonempty");
                emitted += round.len();
                tokens.extend_from_slice(&round);
                continue;
            }
        }
        // Degenerate round (no look-ahead room or no drafts survived):
        // one plain committed step, exactly the solo loop body.
        session.decode_step(next)?;
        next = decode.pick(session.logits(), rng)?;
        tokens.push(next);
        emitted += 1;
    }
}

/// Generate `new_tokens` continuation tokens for `prompt` through a
/// KV-cache [`DecodeSession`] on a private f32 block pool, returning the
/// session's full per-site [`LampStats`] (each causal product counted
/// exactly once). Thin wrapper over [`generate_with_session`].
pub fn generate_with_stats(
    weights: &Weights,
    prompt: &[u32],
    new_tokens: usize,
    prec: impl Into<PrecisionPlan>,
    decode: Decode,
    seed: u64,
) -> Result<(Vec<u32>, LampStats)> {
    let plan: PrecisionPlan = prec.into();
    // Same storage front door as `forward`: a plan that demands a specific
    // weight format is rejected before any decoding happens.
    if !plan.weights.accepts(weights.weight_format()) {
        return Err(Error::config(format!(
            "plan requires {} weight storage, engine holds {}",
            plan.weights.label(),
            weights.weight_format().label()
        )));
    }
    let mut session = DecodeSession::new(weights, plan, seed);
    generate_with_session(&mut session, prompt, new_tokens, decode)
}

/// Generate `new_tokens` continuation tokens for `prompt` through a
/// KV-cache [`DecodeSession`]. Returns (tokens, recompute_rate), where the
/// rate is the attention-site rate over every causal product the session
/// evaluated (each product exactly once).
pub fn generate(
    weights: &Weights,
    prompt: &[u32],
    new_tokens: usize,
    prec: impl Into<PrecisionPlan>,
    decode: Decode,
    seed: u64,
) -> Result<(Vec<u32>, f64)> {
    let (tokens, stats) = generate_with_stats(weights, prompt, new_tokens, prec, decode, seed)?;
    let rate = stats.rate();
    Ok((tokens, rate))
}

/// The original decode loop: re-runs the full forward pass per generated
/// token. Kept as the throughput baseline (`cargo bench --bench decode`)
/// and as the parity oracle for the KV-cache path. Returns
/// (tokens, recompute_rate) with the rate aggregated over every
/// (re-)evaluated pass, as the seed engine reported it.
pub fn generate_reforward(
    weights: &Weights,
    prompt: &[u32],
    new_tokens: usize,
    prec: impl Into<PrecisionPlan>,
    decode: Decode,
    seed: u64,
) -> Result<(Vec<u32>, f64)> {
    if prompt.is_empty() {
        return Err(Error::shape("empty prompt".to_string()));
    }
    let plan: PrecisionPlan = prec.into();
    let cfg = &weights.config;
    let mut tokens = prompt.to_vec();
    let mut rng = Rng::new(seed);
    let mut recomputed = 0usize;
    let mut causal = 0usize;
    for _ in 0..new_tokens {
        if tokens.len() >= cfg.seq {
            break;
        }
        let out = forward(weights, &tokens, plan, seed)?;
        recomputed += out.stats.recomputed;
        causal += out.stats.causal_total;
        let last = out.logits.row(tokens.len() - 1);
        let next = decode.pick(last, &mut rng)?;
        tokens.push(next);
    }
    let rate = if causal == 0 { 0.0 } else { recomputed as f64 / causal as f64 };
    Ok((tokens, rate))
}

/// Top-k temperature sampling from a logits row.
fn sample_topk(logits: &[f32], k: usize, temperature: f32, rng: &mut Rng) -> Result<u32> {
    // NaN temperature must fail here with a typed error, not reach the
    // categorical sampler's assert (the scheduler turns this Err into a
    // single-request failure; a panic would abort the whole serving step).
    if k == 0 || temperature.is_nan() || temperature <= 0.0 {
        return Err(Error::config("top-k needs k >= 1 and temperature > 0".to_string()));
    }
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    idx.truncate(k.min(logits.len()));
    let m = logits[idx[0]];
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - m) / temperature) as f64).exp())
        .collect();
    Ok(idx[rng.categorical(&weights)] as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lamp::softmax::SoftmaxRule;
    use crate::model::attention::AttentionPrecision;
    use crate::model::ModelConfig;

    fn weights() -> Weights {
        let mut rng = Rng::new(1);
        Weights::random(&ModelConfig::nano(), &mut rng).unwrap()
    }

    #[test]
    fn greedy_is_deterministic() {
        let w = weights();
        let prompt = vec![3u32, 14, 15];
        let (a, _) = generate(&w, &prompt, 8, AttentionPrecision::reference(), Decode::Greedy, 0)
            .unwrap();
        let (b, _) = generate(&w, &prompt, 8, AttentionPrecision::reference(), Decode::Greedy, 0)
            .unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 11);
        assert_eq!(&a[..3], &prompt[..]);
    }

    #[test]
    fn respects_context_limit() {
        let w = weights();
        let prompt: Vec<u32> = (0..30).collect();
        let (out, _) =
            generate(&w, &prompt, 10, AttentionPrecision::reference(), Decode::Greedy, 0).unwrap();
        assert!(out.len() <= 32);
        // Prompt already at the limit: nothing to do, nothing to error.
        let full: Vec<u32> = (0..32).collect();
        let (out, rate) =
            generate(&w, &full, 4, AttentionPrecision::reference(), Decode::Greedy, 0).unwrap();
        assert_eq!(out, full);
        assert_eq!(rate, 0.0);
    }

    #[test]
    fn kv_cache_matches_reforward_all_rules() {
        // The engine-rewire contract: the KV-cache decode produces exactly
        // the token stream of the original full-re-forward loop, for
        // deterministic and Random selection alike (per-row streams depend
        // only on the position).
        let w = weights();
        let prompt = vec![7u32, 21, 3, 99];
        for prec in [
            AttentionPrecision::reference(),
            AttentionPrecision::uniform(3),
            AttentionPrecision::lamp(3, 0.02, SoftmaxRule::Strict),
            AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Relaxed),
            AttentionPrecision::lamp(3, 0.05, SoftmaxRule::Random),
        ] {
            let (kv, kv_rate) =
                generate(&w, &prompt, 10, prec, Decode::Greedy, 5).unwrap();
            let (rf, _) =
                generate_reforward(&w, &prompt, 10, prec, Decode::Greedy, 5).unwrap();
            assert_eq!(kv, rf, "token streams diverge at mu={}", prec.mu);
            assert!((0.0..=1.0).contains(&kv_rate));
            // Top-k paths consume the same RNG stream in the same order.
            let d = Decode::TopK { k: 8, temperature: 1.2 };
            let (kv_t, _) = generate(&w, &prompt, 10, prec, d, 5).unwrap();
            let (rf_t, _) = generate_reforward(&w, &prompt, 10, prec, d, 5).unwrap();
            assert_eq!(kv_t, rf_t, "top-k streams diverge at mu={}", prec.mu);
        }
    }

    #[test]
    fn kv_cache_matches_reforward_under_whole_model_plans() {
        // Same contract with every composition site active: the KV-cache
        // token stream equals the full-re-forward stream bit for bit.
        use crate::model::plan::PrecisionPlan;
        let w = weights();
        let prompt = vec![4u32, 19, 88];
        for plan in [
            PrecisionPlan::whole_model(AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Strict)),
            PrecisionPlan::attention_only(AttentionPrecision::lamp(
                3,
                0.05,
                SoftmaxRule::Random,
            ))
            .with_mlp(AttentionPrecision::lamp(4, 0.5, SoftmaxRule::Random))
            .with_norm(AttentionPrecision::uniform(4))
            .with_sampler(AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Random)),
        ] {
            let (kv, _) = generate(&w, &prompt, 8, plan, Decode::Greedy, 6).unwrap();
            let (rf, _) = generate_reforward(&w, &prompt, 8, plan, Decode::Greedy, 6).unwrap();
            assert_eq!(kv, rf, "streams diverge under {plan:?}");
        }
    }

    #[test]
    fn speculative_decode_is_bit_identical_to_solo() {
        // The tentpole oracle: for every (draft plan, k), speculative
        // decode emits exactly the solo non-speculative token stream under
        // the target plan, with single-counted compute stats — greedy and
        // top-k alike.
        use crate::model::plan::{PrecisionPlan, SpecConfig};
        let w = weights();
        let prompt = vec![7u32, 21, 3, 99];
        let target =
            PrecisionPlan::whole_model(AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Strict));
        let (solo, solo_stats) =
            generate_with_stats(&w, &prompt, 12, target, Decode::Greedy, 5).unwrap();
        let topk = Decode::TopK { k: 8, temperature: 1.2 };
        let (solo_t, solo_t_stats) =
            generate_with_stats(&w, &prompt, 12, target, topk, 5).unwrap();
        let mut some_accepted = false;
        for draft in [
            AttentionPrecision::uniform(2),
            AttentionPrecision::uniform(3),
            AttentionPrecision::lamp(3, 0.2, SoftmaxRule::Strict),
            AttentionPrecision::lamp(2, 0.5, SoftmaxRule::Relaxed),
        ] {
            for k in [1usize, 2, 4, 7] {
                let plan = target.with_spec(Some(SpecConfig::whole_model(draft, k)));
                plan.validate().unwrap();
                let (spec, stats) =
                    generate_with_stats(&w, &prompt, 12, plan, Decode::Greedy, 5).unwrap();
                assert_eq!(spec, solo, "greedy stream diverges, draft {draft:?} k={k}");
                assert_eq!(stats.recomputed, solo_stats.recomputed);
                assert_eq!(stats.causal_total, solo_stats.causal_total);
                assert_eq!(stats.per_layer, solo_stats.per_layer);
                assert_eq!(stats.mlp, solo_stats.mlp);
                assert_eq!(stats.norm, solo_stats.norm);
                assert_eq!(stats.sampler, solo_stats.sampler);
                assert!(stats.spec.rounds > 0, "speculation must actually run");
                assert!(stats.spec.drafted >= stats.spec.accepted);
                some_accepted |= stats.spec.accepted > 0;

                let (spec_t, stats_t) =
                    generate_with_stats(&w, &prompt, 12, plan, topk, 5).unwrap();
                assert_eq!(spec_t, solo_t, "top-k stream diverges, draft {draft:?} k={k}");
                assert_eq!(stats_t.sampler, solo_t_stats.sampler);
            }
        }
        assert!(some_accepted, "no draft configuration ever accepted a token");
    }

    #[test]
    fn speculative_decode_respects_context_and_budget_edges() {
        use crate::model::plan::{PrecisionPlan, SpecConfig};
        let w = weights();
        let target =
            PrecisionPlan::whole_model(AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Strict));
        let plan = target
            .with_spec(Some(SpecConfig::whole_model(AttentionPrecision::uniform(2), 3)));
        // Budget of exactly one token: no round fits, still solo-equal.
        let prompt = vec![7u32, 21, 3];
        for budget in [1usize, 2, 40] {
            let (solo, _) =
                generate_with_stats(&w, &prompt, budget, target, Decode::Greedy, 9).unwrap();
            let (spec, _) =
                generate_with_stats(&w, &prompt, budget, plan, Decode::Greedy, 9).unwrap();
            assert_eq!(spec, solo, "budget {budget}: streams diverge");
        }
        // Prompt one below the context window: emits exactly one token.
        let long: Vec<u32> = (0..31).collect();
        let (solo, _) =
            generate_with_stats(&w, &long, 8, target, Decode::Greedy, 9).unwrap();
        let (spec, _) = generate_with_stats(&w, &long, 8, plan, Decode::Greedy, 9).unwrap();
        assert_eq!(spec, solo);
        assert_eq!(spec.len(), 32);
    }

    #[test]
    fn topk_varies_with_seed_greedy_does_not() {
        let w = weights();
        let prompt = vec![1u32, 2];
        let d = Decode::TopK { k: 16, temperature: 1.5 };
        let (a, _) = generate(&w, &prompt, 12, AttentionPrecision::reference(), d, 1).unwrap();
        let (b, _) = generate(&w, &prompt, 12, AttentionPrecision::reference(), d, 2).unwrap();
        assert_ne!(a, b, "different seeds should sample different paths");
    }

    #[test]
    fn lamp_reports_recompute_rate() {
        let w = weights();
        let prompt = vec![5u32, 6, 7, 8];
        let prec = AttentionPrecision::lamp(3, 0.01, crate::lamp::softmax::SoftmaxRule::Strict);
        let (_, rate) = generate(&w, &prompt, 4, prec, Decode::Greedy, 0).unwrap();
        assert!(rate > 0.0 && rate < 1.0, "rate={rate}");
    }

    #[test]
    fn invalid_inputs_rejected() {
        let w = weights();
        assert!(generate(&w, &[], 4, AttentionPrecision::reference(), Decode::Greedy, 0).is_err());
        let bad = Decode::TopK { k: 0, temperature: 1.0 };
        assert!(generate(&w, &[1], 4, AttentionPrecision::reference(), bad, 0).is_err());
        let nan = Decode::TopK { k: 4, temperature: f32::NAN };
        assert!(generate(&w, &[1], 4, AttentionPrecision::reference(), nan, 0).is_err());
        assert!(generate(&w, &[9999], 4, AttentionPrecision::reference(), Decode::Greedy, 0)
            .is_err());
    }

    #[test]
    fn low_precision_perturbs_decoding_distribution() {
        // With random-init weights the attention output is small relative
        // to the embeddings, so argmax flips are not guaranteed — but the
        // logits themselves must differ under PS(1) accumulation. (Actual
        // greedy flips on the *trained* model are covered by the serving
        // integration tests.)
        let w = weights();
        let prompt = vec![3u32, 44, 95, 17, 60, 2, 81, 33];
        let a = forward(&w, &prompt, AttentionPrecision::reference(), 0).unwrap();
        let b = forward(&w, &prompt, AttentionPrecision::uniform(1), 0).unwrap();
        let d = a.logits.max_abs_diff(&b.logits).unwrap();
        assert!(d > 0.0, "PS(1) accumulation left logits bit-identical");
    }
}
