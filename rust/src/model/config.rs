//! Model hyperparameters and the named config registry.
//!
//! The paper evaluates GPT-2 XL and GPT-2 small. Pretrained weights are not
//! available in this environment (see DESIGN.md §Substitutions); the
//! registry defines the scaled-down *-sim configs trained at build time by
//! `python/compile/train.py`, preserving the small-vs-large comparison of
//! Fig. 5.

use crate::config::KvConfig;
use crate::error::{Error, Result};

/// Transformer hyperparameters (GPT-2 architecture).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    /// Registry name ("nano", "small", "xl").
    pub name: String,
    /// Vocabulary size.
    pub vocab: usize,
    /// Maximum sequence length (learned positional embeddings).
    pub seq: usize,
    /// Number of transformer blocks.
    pub layers: usize,
    /// Number of attention heads.
    pub heads: usize,
    /// Model width.
    pub d_model: usize,
    /// Batch size baked into the HLO artifact.
    pub batch: usize,
}

impl ModelConfig {
    /// Per-head dimension.
    pub fn head_dim(&self) -> usize {
        self.d_model / self.heads
    }

    /// MLP hidden width (GPT-2 uses 4×).
    pub fn d_ff(&self) -> usize {
        4 * self.d_model
    }

    /// Total number of KQ inner products in the causal mask for a sequence
    /// of length `s`: heads × layers × s(s+1)/2.
    pub fn causal_products(&self, s: usize) -> usize {
        self.layers * self.heads * s * (s + 1) / 2
    }

    /// Parameter count (with tied embeddings).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let per_layer = 2 * d // ln1
            + d * 3 * d + 3 * d // qkv
            + d * d + d // proj
            + 2 * d // ln2
            + d * self.d_ff() + self.d_ff() // fc
            + self.d_ff() * d + d; // out
        self.vocab * d + self.seq * d + self.layers * per_layer + 2 * d
    }

    /// Test-scale config: 2 layers, d=32.
    pub fn nano() -> Self {
        ModelConfig {
            name: "nano".into(),
            vocab: 128,
            seq: 32,
            layers: 2,
            heads: 2,
            d_model: 32,
            batch: 2,
        }
    }

    /// GPT-2-small analogue (paper App. C.2).
    pub fn small() -> Self {
        ModelConfig {
            name: "small".into(),
            vocab: 512,
            seq: 128,
            layers: 4,
            heads: 4,
            d_model: 128,
            batch: 4,
        }
    }

    /// GPT-2-XL analogue (deeper/wider; the paper's headline model).
    pub fn xl() -> Self {
        ModelConfig {
            name: "xl".into(),
            vocab: 512,
            seq: 128,
            layers: 8,
            heads: 8,
            d_model: 256,
            batch: 4,
        }
    }

    /// Look up a named config.
    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "nano" => Ok(Self::nano()),
            "small" => Ok(Self::small()),
            "xl" => Ok(Self::xl()),
            other => Err(Error::config(format!(
                "unknown model config {other:?} (expected nano|small|xl)"
            ))),
        }
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.d_model % self.heads != 0 {
            return Err(Error::config(format!(
                "d_model {} not divisible by heads {}",
                self.d_model, self.heads
            )));
        }
        if self.vocab == 0 || self.seq == 0 || self.layers == 0 || self.batch == 0 {
            return Err(Error::config("zero-sized model dimension".to_string()));
        }
        Ok(())
    }

    /// Serialize to the `.kv` metadata format shipped next to artifacts.
    pub fn to_kv(&self) -> KvConfig {
        let mut kv = KvConfig::new();
        kv.set("model.name", &self.name);
        kv.set("model.vocab", self.vocab);
        kv.set("model.seq", self.seq);
        kv.set("model.layers", self.layers);
        kv.set("model.heads", self.heads);
        kv.set("model.d_model", self.d_model);
        kv.set("model.batch", self.batch);
        kv
    }

    /// Parse from the `.kv` metadata format.
    pub fn from_kv(kv: &KvConfig) -> Result<Self> {
        let cfg = ModelConfig {
            name: kv.require("model.name")?.to_string(),
            vocab: kv.get_usize("model.vocab")?,
            seq: kv.get_usize("model.seq")?,
            layers: kv.get_usize("model.layers")?,
            heads: kv.get_usize("model.heads")?,
            d_model: kv.get_usize("model.d_model")?,
            batch: kv.get_usize("model.batch")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lookup() {
        assert_eq!(ModelConfig::by_name("xl").unwrap().layers, 8);
        assert_eq!(ModelConfig::by_name("small").unwrap().layers, 4);
        assert!(ModelConfig::by_name("gpt4").is_err());
    }

    #[test]
    fn derived_dims() {
        let c = ModelConfig::xl();
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.d_ff(), 1024);
        c.validate().unwrap();
    }

    #[test]
    fn causal_product_count() {
        let c = ModelConfig::nano();
        // layers(2) * heads(2) * s(s+1)/2 with s=4 → 2*2*10 = 40
        assert_eq!(c.causal_products(4), 40);
    }

    #[test]
    fn xl_larger_than_small() {
        assert!(ModelConfig::xl().param_count() > 2 * ModelConfig::small().param_count());
    }

    #[test]
    fn kv_roundtrip() {
        let c = ModelConfig::small();
        let kv = c.to_kv();
        let c2 = ModelConfig::from_kv(&kv).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ModelConfig::nano();
        c.heads = 3; // 32 % 3 != 0
        assert!(c.validate().is_err());
        let mut c = ModelConfig::nano();
        c.layers = 0;
        assert!(c.validate().is_err());
    }
}
