//! GPT-2-architecture transformer with LAMP mixed-precision attention —
//! the **native engine**.
//!
//! This is a bit-exact Rust implementation of the same computation the L2
//! JAX model (`python/compile/model.py`) lowers to HLO: pre-LN GPT-2 blocks
//! whose key-query inner products are accumulated in PS(μ) with per-step
//! rounding (paper §4.1) and selectively recomputed in FP32 according to a
//! LAMP rule (§3.3/§4.4). Everything else runs in FP32, exactly as the
//! paper's experimental setting prescribes.
//!
//! The native engine exists for three reasons:
//! 1. *parity testing* — the PJRT engine is validated against it;
//! 2. *instrumentation* — per-layer/per-head recomputation statistics;
//! 3. *fast sweeps* — the experiment harness evaluates hundreds of (μ, τ)
//!    points without FFI round trips.

pub mod attention;
pub mod config;
pub mod forward;
pub mod kvcache;
pub mod layernorm;
pub mod loss;
pub mod mlp;
pub mod sampler;
pub mod weights;

pub use attention::{AttentionPrecision, LampStats};
pub use config::ModelConfig;
pub use forward::{forward, forward_with, ForwardOutput, ForwardScratch};
pub use kvcache::DecodeSession;
pub use sampler::{generate, generate_reforward, Decode};
pub use weights::Weights;
