//! GPT-2-architecture transformer with whole-model LAMP mixed precision —
//! the **native engine**.
//!
//! This is a bit-exact Rust implementation of the same computation the L2
//! JAX model (`python/compile/model.py`) lowers to HLO: pre-LN GPT-2
//! blocks whose compositions f(g(x)) run low precision with look-ahead
//! repair according to a per-site [`PrecisionPlan`]:
//!
//! * key-query inner products accumulated in PS(μ) with per-step rounding
//!   (paper §4.1) and selectively recomputed in FP32 by a softmax LAMP
//!   rule (§3.3/§4.4) — the attention site;
//! * MLP fc/proj matmuls in PS(μ) with GELU-sensitivity-guided fc repair
//!   (§3.1) — the mlp site;
//! * the final residual stored in PS(μ) with RMS-norm-guided restoration
//!   (§3.2) — the norm site;
//! * logit inner products in PS(μ) with softmax-rule repair over the
//!   sampling distribution — the sampler site.
//!
//! A plan whose non-attention sites are all at reference reproduces the
//! paper's attention-only experimental setting bit for bit.
//!
//! Orthogonally to *compute* precision, parameters live in mixed-precision
//! *storage* ([`crate::linalg::WeightTensor`]: f32 / bf16 / PS(μ)-rounded;
//! [`Weights::quantize_to`]). Every stored value is an exact f32, so the
//! whole plan machinery — selection, FP32 repair, decode parity — carries
//! over unchanged under quantized storage; f32 storage is bit-identical
//! to the historical `Matrix`-backed weights.
//!
//! The native engine exists for three reasons:
//! 1. *parity testing* — the PJRT engine is validated against it;
//! 2. *instrumentation* — per-layer/per-site recomputation statistics;
//! 3. *fast sweeps* — the experiment harness evaluates hundreds of (μ, τ)
//!    points without FFI round trips.

pub mod attention;
pub mod config;
pub mod forward;
pub mod kvcache;
pub mod kvstore;
pub mod layernorm;
pub mod loss;
pub mod mlp;
pub mod plan;
pub mod sampler;
pub mod weights;

pub use attention::{AttentionPrecision, LampStats, RowLamp, SiteStats, SpecStats};
pub use config::ModelConfig;
pub use forward::{forward, forward_with, ForwardOutput, ForwardScratch};
pub use kvcache::{DecodeSession, StepFaultVerdict, StepFaults};
pub use kvstore::{KvBlockPool, KvCacheOptions, KvCheckpoint, KvPoolStats, PagedKvCache};
pub use plan::{KvPrecision, PrecisionPlan, SitePrecision, SpecConfig, WeightPrecision};
pub use sampler::{
    generate, generate_reforward, generate_with_session, generate_with_stats, Decode,
};
pub use weights::Weights;
