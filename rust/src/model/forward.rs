//! Full GPT-2 forward pass under a whole-model [`PrecisionPlan`]
//! (native engine).
//!
//! Two entry points:
//! * [`forward`] — convenience wrapper: allocates its own scratch, runs
//!   sequentially. Semantics of the original engine.
//! * [`forward_with`] — the production path: reuses a caller-owned
//!   [`ForwardScratch`] (zero heap traffic once warm) and optionally tiles
//!   attention across a [`ThreadPool`]. Bit-identical to [`forward`] for
//!   every precision plan — see DESIGN.md §Bit-exactness.
//!
//! Both take anything convertible into a [`PrecisionPlan`]; passing a bare
//! [`AttentionPrecision`](super::attention::AttentionPrecision) yields the
//! attention-only plan (every other site at reference), which reproduces
//! the pre-plan engine bit for bit.

use super::attention::{causal_attention_into, LampStats};
use super::config::ModelConfig;
use super::layernorm::{layernorm, LN_EPS};
use super::mlp::mlp_into;
use super::plan::{logits_row_site, norm_site_row, site_row_seed, PrecisionPlan};
use super::plan::{SITE_NORM, SITE_SAMPLER};
use super::weights::Weights;
use crate::error::{Error, Result};
use crate::linalg::matmul::{matmul_bias_into_wt, matmul_transposed_fast_wt};
use crate::linalg::Matrix;
use crate::util::ThreadPool;

/// Output of a forward pass over one sequence.
#[derive(Debug, Clone)]
pub struct ForwardOutput {
    /// Logits [S, vocab].
    pub logits: Matrix,
    /// LAMP recomputation statistics.
    pub stats: LampStats,
}

/// Reusable buffers for [`forward_with`]. One scratch serves any sequence
/// length up to the longest it has seen (buffers only ever grow); the
/// per-layer `x.clone()` pre-LN copies, the QKV split into three fresh
/// matrices, and the per-row score vectors of the original engine all
/// land here instead of the allocator.
#[derive(Debug, Default)]
pub struct ForwardScratch {
    /// Residual stream [S, d].
    x: Matrix,
    /// Pre-LN copy of the residual [S, d].
    xn: Matrix,
    /// Fused QKV projection [S, 3d].
    qkv: Matrix,
    q: Matrix,
    k: Matrix,
    v: Matrix,
    /// Attention output [S, d].
    attn: Matrix,
    /// Attention/MLP projection back into the residual [S, d].
    proj: Matrix,
    /// MLP hidden activations [S, d_ff].
    hidden: Matrix,
    /// MLP output [S, d].
    mlp_out: Matrix,
    /// Quantized-row scratch for the final-norm site [d].
    normq: Vec<f32>,
}

impl ForwardScratch {
    /// Fresh scratch; buffers are sized lazily on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Scratch pre-sized for `cfg` at full context length, so the serving
    /// path never allocates mid-request.
    pub fn for_config(cfg: &ModelConfig) -> Self {
        let mut s = Self::new();
        s.reserve(cfg.seq, cfg);
        s
    }

    fn reserve(&mut self, s: usize, cfg: &ModelConfig) {
        let d = cfg.d_model;
        self.x.resize(s, d);
        self.xn.resize(s, d);
        self.qkv.resize(s, 3 * d);
        self.q.resize(s, d);
        self.k.resize(s, d);
        self.v.resize(s, d);
        self.attn.resize(s, d);
        self.proj.resize(s, d);
        self.hidden.resize(s, cfg.d_ff());
        self.mlp_out.resize(s, d);
        if self.normq.capacity() < d {
            self.normq.reserve(d - self.normq.len());
        }
    }
}

/// The per-layer attention seed: folds the layer index into the pass seed
/// so every (layer, head, row) triple draws from its own stream (the
/// `Random` rule's order-independence contract).
///
/// The multiplier must differ from the head fold's constant in
/// [`super::attention::row_stream_seed`] — with a shared constant the two
/// XOR terms cancel whenever `layer == head + 1`, silently collapsing
/// distinct (layer, head) pairs onto one stream.
#[inline]
pub(crate) fn layer_seed(seed: u64, layer: usize) -> u64 {
    seed ^ (layer as u64 + 1).wrapping_mul(0xA24BAED4963EE407)
}

/// Run the model over one token sequence.
///
/// * `tokens` — token ids; length must be ≤ `config.seq`.
/// * `prec` — a [`PrecisionPlan`], or anything convertible into one (a
///   bare [`AttentionPrecision`](super::attention::AttentionPrecision)
///   yields the attention-only plan).
/// * `seed` — RNG seed for the `Random` selection rules (deterministic
///   given (seed, site, layer, head, row) so runs are reproducible and
///   execution order is immaterial).
pub fn forward(
    weights: &Weights,
    tokens: &[u32],
    prec: impl Into<PrecisionPlan>,
    seed: u64,
) -> Result<ForwardOutput> {
    let mut scratch = ForwardScratch::new();
    forward_with(weights, tokens, prec, seed, &mut scratch, None)
}

/// [`forward`] with caller-owned scratch and optional attention-tile
/// parallelism. Bit-identical to [`forward`] regardless of `pool`.
pub fn forward_with(
    weights: &Weights,
    tokens: &[u32],
    prec: impl Into<PrecisionPlan>,
    seed: u64,
    scratch: &mut ForwardScratch,
    pool: Option<&ThreadPool>,
) -> Result<ForwardOutput> {
    let plan: PrecisionPlan = prec.into();
    let cfg: &ModelConfig = &weights.config;
    // The plan's storage requirement is checked against the actual weights
    // at the same front door as the shape checks (the coordinator applies
    // the equivalent gate at submit via `Engine::validate_policy`).
    if !plan.weights.accepts(weights.weight_format()) {
        return Err(Error::config(format!(
            "plan requires {} weight storage, engine holds {}",
            plan.weights.label(),
            weights.weight_format().label()
        )));
    }
    let s = tokens.len();
    if s == 0 || s > cfg.seq {
        return Err(Error::shape(format!(
            "sequence length {s} out of 1..={}",
            cfg.seq
        )));
    }
    for &t in tokens {
        if t as usize >= cfg.vocab {
            return Err(Error::shape(format!("token {t} >= vocab {}", cfg.vocab)));
        }
    }
    let d = cfg.d_model;
    scratch.reserve(s, cfg);

    // Embedding: wte[token] + wpe[pos], dequantized from storage (exact;
    // copy-then-add is the same single f32 add per element as the
    // historical te[c] + pe[c] loop).
    let x = &mut scratch.x;
    for (i, &t) in tokens.iter().enumerate() {
        let xr = x.row_mut(i);
        weights.wte.copy_row_into(t as usize, xr);
        weights.wpe.add_row_into(i, xr);
    }

    let mut stats = LampStats {
        recomputed: 0,
        causal_total: cfg.layers * cfg.heads * s * (s + 1) / 2,
        per_layer: vec![0; cfg.layers],
        ..LampStats::default()
    };

    for (l, blk) in weights.blocks.iter().enumerate() {
        // --- Attention sublayer (pre-LN). ---
        scratch.xn.copy_from(&scratch.x);
        for i in 0..s {
            layernorm(scratch.xn.row_mut(i), &blk.ln1_g, &blk.ln1_b, LN_EPS);
        }
        // QKV projection (FP32, vectorized — not part of the PS(μ) path),
        // reading the stored weights directly (fused dequant).
        matmul_bias_into_wt(&scratch.xn, &blk.w_qkv, &blk.b_qkv, &mut scratch.qkv)?;
        for i in 0..s {
            let row = scratch.qkv.row(i);
            scratch.q.row_mut(i).copy_from_slice(&row[..d]);
            scratch.k.row_mut(i).copy_from_slice(&row[d..2 * d]);
            scratch.v.row_mut(i).copy_from_slice(&row[2 * d..]);
        }
        let layer_lamp = causal_attention_into(
            &scratch.q,
            &scratch.k,
            &scratch.v,
            cfg.heads,
            plan.attention,
            layer_seed(seed, l),
            pool,
            &mut scratch.attn,
        );
        stats.per_layer[l] = layer_lamp.recomputed;
        stats.recomputed += layer_lamp.recomputed;
        stats.tiles.recomputed += layer_lamp.tiles;
        stats.tiles.total += layer_lamp.tiles_total;
        // Output projection + residual.
        matmul_bias_into_wt(&scratch.attn, &blk.w_proj, &blk.b_proj, &mut scratch.proj)?;
        for i in 0..s {
            let pr = scratch.proj.row(i);
            let xr = scratch.x.row_mut(i);
            for c in 0..d {
                xr[c] += pr[c];
            }
        }

        // --- MLP sublayer (pre-LN). ---
        scratch.xn.copy_from(&scratch.x);
        for i in 0..s {
            layernorm(scratch.xn.row_mut(i), &blk.ln2_g, &blk.ln2_b, LN_EPS);
        }
        let mlp_recomputed = mlp_into(
            &scratch.xn,
            &blk.w_fc,
            &blk.b_fc,
            &blk.w_out,
            &blk.b_out,
            plan.mlp,
            layer_seed(seed, l),
            &mut scratch.hidden,
            &mut scratch.mlp_out,
        )?;
        stats.mlp.recomputed += mlp_recomputed;
        stats.mlp.total += s * cfg.d_ff();
        for i in 0..s {
            let mr = scratch.mlp_out.row(i);
            let xr = scratch.x.row_mut(i);
            for c in 0..d {
                xr[c] += mr[c];
            }
        }
    }

    // Final-norm site: PS(μ) residual storage with RMS-guided restoration
    // (no-op at reference), then the final LN.
    if !plan.norm.is_reference() {
        for i in 0..s {
            stats.norm.recomputed += norm_site_row(
                scratch.x.row_mut(i),
                plan.norm,
                site_row_seed(seed, SITE_NORM, i),
                &mut scratch.normq,
            );
        }
    }
    stats.norm.total += s * d;
    for i in 0..s {
        layernorm(scratch.x.row_mut(i), &weights.lnf_g, &weights.lnf_b, LN_EPS);
    }

    // Sampler site + tied unembedding. The logits matrix is the caller's
    // deliverable, so it is the one allocation of the pass.
    stats.sampler.total += s * cfg.vocab;
    let logits = if plan.sampler.is_reference() {
        matmul_transposed_fast_wt(&scratch.x, &weights.wte)?
    } else {
        let mut m = Matrix::zeros(s, cfg.vocab);
        for i in 0..s {
            stats.sampler.recomputed += logits_row_site(
                scratch.x.row(i),
                &weights.wte,
                plan.sampler,
                site_row_seed(seed, SITE_SAMPLER, i),
                m.row_mut(i),
            );
        }
        m
    };
    Ok(ForwardOutput { logits, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lamp::softmax::SoftmaxRule;
    use crate::model::attention::AttentionPrecision;
    use crate::util::Rng;

    fn nano_weights(seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        Weights::random(&ModelConfig::nano(), &mut rng).unwrap()
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let w = nano_weights(1);
        let tokens: Vec<u32> = vec![1, 5, 9, 2, 7];
        let a = forward(&w, &tokens, AttentionPrecision::reference(), 0).unwrap();
        let b = forward(&w, &tokens, AttentionPrecision::reference(), 0).unwrap();
        assert_eq!(a.logits.shape(), (5, 128));
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.stats.recomputed, 0);
        assert_eq!(a.stats.causal_total, 2 * 2 * 15);
    }

    #[test]
    fn weight_storage_requirement_gated_at_forward() {
        use super::super::plan::WeightPrecision;
        use crate::linalg::WeightFormat;
        let w = nano_weights(11);
        let plan = PrecisionPlan::reference()
            .with_weights(WeightPrecision::Exact(WeightFormat::Bf16));
        assert!(
            forward(&w, &[1, 2], plan, 0).is_err(),
            "f32 engine must reject a bf16-storage requirement"
        );
        let q = w.quantize_to(WeightFormat::Bf16).unwrap();
        forward(&q, &[1, 2], plan, 0).unwrap();
        // The default Any requirement accepts every storage.
        forward(&q, &[1, 2], PrecisionPlan::reference(), 0).unwrap();
    }

    #[test]
    fn quantized_storage_forward_matches_dequantized_storage_bitwise() {
        // The fused-dequant kernels' whole-model consequence: running on
        // bf16 storage equals running on the f32 storage holding exactly
        // the dequantized values — quantization error enters once, at
        // quantize_to, never per-kernel.
        use crate::linalg::WeightFormat;
        let w = nano_weights(12);
        let tokens: Vec<u32> = (0..12).map(|i| (i * 9 + 4) % 128).collect();
        for fmt in [WeightFormat::Bf16, WeightFormat::PsRounded { mu: 7 }] {
            let q = w.quantize_to(fmt).unwrap();
            let deq = q.quantize_to(WeightFormat::F32).unwrap();
            for plan in [
                PrecisionPlan::reference(),
                PrecisionPlan::whole_model(AttentionPrecision::lamp(
                    3,
                    0.1,
                    SoftmaxRule::Strict,
                )),
            ] {
                let a = forward(&q, &tokens, plan, 5).unwrap();
                let b = forward(&deq, &tokens, plan, 5).unwrap();
                assert_eq!(a.logits, b.logits, "{fmt:?} fused != dequantized");
                assert_eq!(a.stats.recomputed, b.stats.recomputed);
                assert_eq!(a.stats.mlp, b.stats.mlp);
            }
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        let w = nano_weights(2);
        assert!(forward(&w, &[], AttentionPrecision::reference(), 0).is_err());
        let too_long: Vec<u32> = vec![0; 33];
        assert!(forward(&w, &too_long, AttentionPrecision::reference(), 0).is_err());
        assert!(forward(&w, &[999], AttentionPrecision::reference(), 0).is_err());
    }

    #[test]
    fn scratch_reuse_and_pool_are_bit_identical() {
        // One scratch across many calls of varying lengths and policies,
        // with and without a pool, must reproduce the fresh-scratch
        // sequential pass bit-for-bit.
        let w = nano_weights(7);
        let pool = ThreadPool::new(3);
        let mut scratch = ForwardScratch::for_config(&w.config);
        let seqs: Vec<Vec<u32>> = vec![
            (0..20).map(|i| (i * 5 + 1) % 128).collect(),
            vec![3, 14, 15],
            (0..32).map(|i| (i * 11 + 2) % 128).collect(),
            vec![42],
        ];
        let plans: Vec<PrecisionPlan> = vec![
            AttentionPrecision::reference().into(),
            AttentionPrecision::uniform(3).into(),
            AttentionPrecision::lamp(3, 0.02, SoftmaxRule::Strict).into(),
            AttentionPrecision::lamp(3, 0.05, SoftmaxRule::Random).into(),
            PrecisionPlan::whole_model(AttentionPrecision::lamp(
                3,
                0.1,
                SoftmaxRule::Strict,
            )),
            PrecisionPlan::attention_only(AttentionPrecision::lamp(
                3,
                0.05,
                SoftmaxRule::Random,
            ))
            .with_mlp(AttentionPrecision::lamp(4, 0.5, SoftmaxRule::Random))
            .with_norm(AttentionPrecision::lamp(4, 0.3, SoftmaxRule::Random))
            .with_sampler(AttentionPrecision::lamp(4, 0.1, SoftmaxRule::Random)),
        ];
        for plan in plans {
            for tokens in &seqs {
                let fresh = forward(&w, tokens, plan, 9).unwrap();
                let reused =
                    forward_with(&w, tokens, plan, 9, &mut scratch, None).unwrap();
                let pooled =
                    forward_with(&w, tokens, plan, 9, &mut scratch, Some(&pool)).unwrap();
                assert_eq!(fresh.logits, reused.logits, "scratch reuse changed logits");
                assert_eq!(fresh.logits, pooled.logits, "pool changed logits");
                assert_eq!(fresh.stats.recomputed, reused.stats.recomputed);
                assert_eq!(fresh.stats.recomputed, pooled.stats.recomputed);
                assert_eq!(fresh.stats.per_layer, pooled.stats.per_layer);
                assert_eq!(fresh.stats.mlp, pooled.stats.mlp);
                assert_eq!(fresh.stats.norm, pooled.stats.norm);
                assert_eq!(fresh.stats.sampler, pooled.stats.sampler);
            }
        }
    }

    #[test]
    fn whole_model_plan_activates_every_site() {
        let w = nano_weights(9);
        let tokens: Vec<u32> = (0..16).map(|i| (i * 13 + 2) % 128).collect();
        let plan = PrecisionPlan::attention_only(AttentionPrecision::lamp(
            3,
            0.02,
            SoftmaxRule::Strict,
        ))
        .with_mlp(AttentionPrecision::lamp(3, 0.5, SoftmaxRule::Strict))
        .with_norm(AttentionPrecision::lamp(3, 0.5, SoftmaxRule::Strict))
        .with_sampler(AttentionPrecision::lamp(3, 0.0, SoftmaxRule::Strict));
        let out = forward(&w, &tokens, plan, 4).unwrap();
        let cfg = &w.config;
        assert!(out.stats.recomputed > 0, "attention site inactive");
        assert!(out.stats.mlp.recomputed > 0, "mlp site inactive");
        assert!(out.stats.norm.recomputed > 0, "norm site inactive");
        assert!(out.stats.sampler.recomputed > 0, "sampler site inactive");
        assert_eq!(out.stats.mlp.total, cfg.layers * tokens.len() * cfg.d_ff());
        assert_eq!(out.stats.norm.total, tokens.len() * cfg.d_model);
        assert_eq!(out.stats.sampler.total, tokens.len() * cfg.vocab);
        // Reference plans evaluate the same totals with zero recomputation.
        let reference = forward(&w, &tokens, PrecisionPlan::reference(), 4).unwrap();
        assert_eq!(reference.stats.mlp.recomputed, 0);
        assert_eq!(reference.stats.mlp.total, out.stats.mlp.total);
    }

    #[test]
    fn low_precision_changes_logits_lamp_repairs() {
        let w = nano_weights(3);
        let tokens: Vec<u32> = (0..16).map(|i| (i * 7 + 3) % 128).collect();
        let reference = forward(&w, &tokens, AttentionPrecision::reference(), 0).unwrap();
        let uniform = forward(&w, &tokens, AttentionPrecision::uniform(2), 0).unwrap();
        let lamp = forward(
            &w,
            &tokens,
            AttentionPrecision::lamp(2, 0.01, SoftmaxRule::Strict),
            0,
        )
        .unwrap();
        let e_uni = uniform.logits.max_abs_diff(&reference.logits).unwrap();
        let e_lamp = lamp.logits.max_abs_diff(&reference.logits).unwrap();
        assert!(e_uni > 0.0, "PS(2) must perturb logits");
        assert!(lamp.stats.recomputed > 0);
        assert!(
            e_lamp < e_uni,
            "LAMP must reduce the deviation: lamp={e_lamp} uniform={e_uni}"
        );
    }

    #[test]
    fn causal_prefix_property() {
        // Logits at position i must not depend on tokens after i.
        let w = nano_weights(4);
        let t1: Vec<u32> = vec![3, 14, 15, 92, 65];
        let mut t2 = t1.clone();
        t2[4] = 35; // change the last token
        let a = forward(&w, &t1, AttentionPrecision::reference(), 0).unwrap();
        let b = forward(&w, &t2, AttentionPrecision::reference(), 0).unwrap();
        for i in 0..4 {
            for c in 0..128 {
                assert_eq!(a.logits.get(i, c), b.logits.get(i, c), "pos {i}");
            }
        }
    }

    #[test]
    fn rng_streams_distinct_across_layer_head_row() {
        // Regression: layer_seed and row_stream_seed once shared a fold
        // multiplier, cancelling whenever layer == head + 1 and collapsing
        // distinct (layer, head) pairs onto one Random-rule stream.
        use super::super::attention::row_stream_seed;
        let mut seen = std::collections::HashSet::new();
        for l in 0..8 {
            for h in 0..8 {
                for row in 0..8 {
                    let s = row_stream_seed(layer_seed(7, l), h, row);
                    assert!(
                        seen.insert(s),
                        "stream collision at layer={l} head={h} row={row}"
                    );
                }
            }
        }
    }

    #[test]
    fn random_rule_matches_strict_count() {
        let w = nano_weights(5);
        let tokens: Vec<u32> = (0..12).map(|i| (i * 11) % 128).collect();
        let strict = forward(
            &w,
            &tokens,
            AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Strict),
            7,
        )
        .unwrap();
        let random = forward(
            &w,
            &tokens,
            AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Random),
            7,
        )
        .unwrap();
        // Counts derive from the strict rule on the *same low-precision
        // scores of that pass*; downstream activations diverge after the
        // first random recomputation, so allow a small relative gap.
        let a = strict.stats.recomputed as f64;
        let b = random.stats.recomputed as f64;
        assert!(
            (a - b).abs() <= 0.25 * a.max(8.0),
            "counts far apart: strict={a} random={b}"
        );
    }
}
