//! Full GPT-2 forward pass with LAMP attention (native engine).

use super::attention::{causal_attention, AttentionPrecision, LampStats};
use super::config::ModelConfig;
use super::layernorm::{layernorm, LN_EPS};
use super::mlp::mlp;
use super::weights::Weights;
use crate::error::{Error, Result};
use crate::linalg::matmul::{matmul_bias_fast, matmul_transposed_fast};
use crate::linalg::Matrix;
use crate::util::Rng;

/// Output of a forward pass over one sequence.
#[derive(Debug, Clone)]
pub struct ForwardOutput {
    /// Logits [S, vocab].
    pub logits: Matrix,
    /// LAMP recomputation statistics.
    pub stats: LampStats,
}

/// Run the model over one token sequence.
///
/// * `tokens` — token ids; length must be ≤ `config.seq`.
/// * `prec` — attention precision policy (μ, τ, rule).
/// * `seed` — RNG seed for the `Random` selection rule (deterministic
///   given (seed, layer, head) so runs are reproducible).
pub fn forward(
    weights: &Weights,
    tokens: &[u32],
    prec: AttentionPrecision,
    seed: u64,
) -> Result<ForwardOutput> {
    let cfg: &ModelConfig = &weights.config;
    let s = tokens.len();
    if s == 0 || s > cfg.seq {
        return Err(Error::shape(format!(
            "sequence length {s} out of 1..={}",
            cfg.seq
        )));
    }
    for &t in tokens {
        if t as usize >= cfg.vocab {
            return Err(Error::shape(format!("token {t} >= vocab {}", cfg.vocab)));
        }
    }
    let d = cfg.d_model;

    // Embedding: wte[token] + wpe[pos].
    let mut x = Matrix::zeros(s, d);
    for (i, &t) in tokens.iter().enumerate() {
        let te = weights.wte.row(t as usize);
        let pe = weights.wpe.row(i);
        let xr = x.row_mut(i);
        for c in 0..d {
            xr[c] = te[c] + pe[c];
        }
    }

    let mut stats = LampStats {
        recomputed: 0,
        causal_total: cfg.layers * cfg.heads * s * (s + 1) / 2,
        per_layer: vec![0; cfg.layers],
    };

    for (l, blk) in weights.blocks.iter().enumerate() {
        // --- Attention sublayer (pre-LN). ---
        let mut xn = x.clone();
        for i in 0..s {
            layernorm(xn.row_mut(i), &blk.ln1_g, &blk.ln1_b, LN_EPS);
        }
        // QKV projection (FP32, vectorized — not part of the PS(μ) path).
        let qkv = matmul_bias_fast(&xn, &blk.w_qkv, &blk.b_qkv)?;
        let mut q = Matrix::zeros(s, d);
        let mut k = Matrix::zeros(s, d);
        let mut v = Matrix::zeros(s, d);
        for i in 0..s {
            let row = qkv.row(i);
            q.row_mut(i).copy_from_slice(&row[..d]);
            k.row_mut(i).copy_from_slice(&row[d..2 * d]);
            v.row_mut(i).copy_from_slice(&row[2 * d..]);
        }
        // LAMP attention; per-layer RNG stream for the Random rule.
        let mut layer_rng = Rng::new(seed ^ (l as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut layer_recomputed = 0usize;
        let attn = causal_attention(&q, &k, &v, cfg.heads, prec, &mut layer_rng, &mut layer_recomputed);
        stats.per_layer[l] = layer_recomputed;
        stats.recomputed += layer_recomputed;
        // Output projection + residual.
        let proj = matmul_bias_fast(&attn, &blk.w_proj, &blk.b_proj)?;
        for i in 0..s {
            let pr = proj.row(i);
            let xr = x.row_mut(i);
            for c in 0..d {
                xr[c] += pr[c];
            }
        }

        // --- MLP sublayer (pre-LN). ---
        let mut xn = x.clone();
        for i in 0..s {
            layernorm(xn.row_mut(i), &blk.ln2_g, &blk.ln2_b, LN_EPS);
        }
        let m = mlp(&xn, &blk.w_fc, &blk.b_fc, &blk.w_out, &blk.b_out);
        for i in 0..s {
            let mr = m.row(i);
            let xr = x.row_mut(i);
            for c in 0..d {
                xr[c] += mr[c];
            }
        }
    }

    // Final LN + tied unembedding.
    for i in 0..s {
        layernorm(x.row_mut(i), &weights.lnf_g, &weights.lnf_b, LN_EPS);
    }
    let logits = matmul_transposed_fast(&x, &weights.wte)?;
    Ok(ForwardOutput { logits, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lamp::softmax::SoftmaxRule;

    fn nano_weights(seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        Weights::random(&ModelConfig::nano(), &mut rng)
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let w = nano_weights(1);
        let tokens: Vec<u32> = vec![1, 5, 9, 2, 7];
        let a = forward(&w, &tokens, AttentionPrecision::reference(), 0).unwrap();
        let b = forward(&w, &tokens, AttentionPrecision::reference(), 0).unwrap();
        assert_eq!(a.logits.shape(), (5, 128));
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.stats.recomputed, 0);
        assert_eq!(a.stats.causal_total, 2 * 2 * 15);
    }

    #[test]
    fn rejects_bad_inputs() {
        let w = nano_weights(2);
        assert!(forward(&w, &[], AttentionPrecision::reference(), 0).is_err());
        let too_long: Vec<u32> = vec![0; 33];
        assert!(forward(&w, &too_long, AttentionPrecision::reference(), 0).is_err());
        assert!(forward(&w, &[999], AttentionPrecision::reference(), 0).is_err());
    }

    #[test]
    fn low_precision_changes_logits_lamp_repairs() {
        let w = nano_weights(3);
        let tokens: Vec<u32> = (0..16).map(|i| (i * 7 + 3) % 128).collect();
        let reference = forward(&w, &tokens, AttentionPrecision::reference(), 0).unwrap();
        let uniform = forward(&w, &tokens, AttentionPrecision::uniform(2), 0).unwrap();
        let lamp = forward(
            &w,
            &tokens,
            AttentionPrecision::lamp(2, 0.01, SoftmaxRule::Strict),
            0,
        )
        .unwrap();
        let e_uni = uniform.logits.max_abs_diff(&reference.logits).unwrap();
        let e_lamp = lamp.logits.max_abs_diff(&reference.logits).unwrap();
        assert!(e_uni > 0.0, "PS(2) must perturb logits");
        assert!(lamp.stats.recomputed > 0);
        assert!(
            e_lamp < e_uni,
            "LAMP must reduce the deviation: lamp={e_lamp} uniform={e_uni}"
        );
    }

    #[test]
    fn causal_prefix_property() {
        // Logits at position i must not depend on tokens after i.
        let w = nano_weights(4);
        let t1: Vec<u32> = vec![3, 14, 15, 92, 65];
        let mut t2 = t1.clone();
        t2[4] = 35; // change the last token
        let a = forward(&w, &t1, AttentionPrecision::reference(), 0).unwrap();
        let b = forward(&w, &t2, AttentionPrecision::reference(), 0).unwrap();
        for i in 0..4 {
            for c in 0..128 {
                assert_eq!(a.logits.get(i, c), b.logits.get(i, c), "pos {i}");
            }
        }
    }

    #[test]
    fn random_rule_matches_strict_count() {
        let w = nano_weights(5);
        let tokens: Vec<u32> = (0..12).map(|i| (i * 11) % 128).collect();
        let strict = forward(
            &w,
            &tokens,
            AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Strict),
            7,
        )
        .unwrap();
        let random = forward(
            &w,
            &tokens,
            AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Random),
            7,
        )
        .unwrap();
        // Counts derive from the strict rule on the *same low-precision
        // scores of that pass*; downstream activations diverge after the
        // first random recomputation, so allow a small relative gap.
        let a = strict.stats.recomputed as f64;
        let b = random.stats.recomputed as f64;
        assert!(
            (a - b).abs() <= 0.25 * a.max(8.0),
            "counts far apart: strict={a} random={b}"
        );
    }
}
