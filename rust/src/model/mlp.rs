//! GPT-2 MLP block: fc → GELU → out — a whole-model LAMP composition site.
//!
//! Under the [`PrecisionPlan`](super::plan::PrecisionPlan)'s MLP site, the
//! fc and proj matmuls accumulate in PS(μ) with per-step rounding
//! ([`matvec_ps_bias_into_wt`]) and the GELU ∘ fc composition is repaired
//! by look-ahead recomputation (paper §3.1): the diagonal sensitivity
//! `|φ′(ŷ)·ŷ/φ(ŷ)|` of the *low-precision* pre-activations flags the
//! hidden units whose fc inner products are recomputed in FP32
//! ([`matvec_col_f32_wt`]) before the nonlinearity. The proj matmul has no
//! downstream nonlinearity to guide a selection, so it runs uniform PS(μ).
//! A reference site (μ=23, τ=∞) short-circuits to the vectorized FP32
//! path, bit-identical to the pre-plan engine.
//!
//! Every kernel reads the [`WeightTensor`] storage directly (fused, exact
//! dequantization), so all of the above holds unchanged under f32, bf16,
//! or PS(μ)-rounded weight storage.

use crate::error::{Error, Result};
use crate::lamp::activation::{select_activation_rule, Activation};
use crate::linalg::matmul::{
    matmul_bias_into_wt, matvec_bias_into_wt, matvec_col_f32_wt, matvec_ps_bias_into_wt,
};
use crate::linalg::{Matrix, WeightTensor};
use crate::model::plan::{site_row_seed, SitePrecision, SITE_MLP};
use crate::util::Rng;

/// One row of the MLP sublayer under the plan's MLP site, writing the
/// hidden pre-activations and the output row into caller-owned scratch.
/// Shared by the batched [`mlp_into`] and the KV-cache decode step, which
/// runs the identical op sequence on its single row — that shared kernel
/// is what keeps incremental decode bit-identical to the full pass under
/// every plan (DESIGN.md §Bit-exactness). `row_seed` feeds the `Random`
/// rule's stream and must be a function of the row's position only.
///
/// Returns the number of fc inner products recomputed in FP32.
#[allow(clippy::too_many_arguments)]
pub fn mlp_row_into(
    xn: &[f32],
    w_fc: &WeightTensor,
    b_fc: &[f32],
    w_out: &WeightTensor,
    b_out: &[f32],
    site: SitePrecision,
    row_seed: u64,
    hidden: &mut [f32],
    out: &mut [f32],
) -> usize {
    let _t = crate::obs::timers::scoped(crate::obs::timers::Site::Mlp);
    debug_assert_eq!(xn.len(), w_fc.rows());
    debug_assert_eq!(hidden.len(), w_fc.cols());
    debug_assert_eq!(out.len(), w_out.cols());
    if site.is_reference() {
        matvec_bias_into_wt(xn, w_fc, b_fc, hidden);
        for h in hidden.iter_mut() {
            *h = Activation::Gelu.apply(*h);
        }
        matvec_bias_into_wt(hidden, w_out, b_out, out);
        return 0;
    }
    // Step 1: PS(μ) accumulation of the fc pre-activations.
    matvec_ps_bias_into_wt(xn, w_fc, b_fc, site.mu, hidden);
    // Steps 2–3: closed-form activation selection + FP32 recomputation.
    let mut recomputed = 0;
    if site.tau.is_finite() {
        let mut rng = Rng::new(row_seed);
        let mask =
            select_activation_rule(hidden, Activation::Gelu, site.tau, site.rule, &mut rng);
        for (j, &m) in mask.iter().enumerate() {
            if m {
                hidden[j] = matvec_col_f32_wt(xn, w_fc, b_fc, j);
                recomputed += 1;
            }
        }
    }
    // Step 4: the nonlinearity, then the (uniform PS) output projection.
    for h in hidden.iter_mut() {
        *h = Activation::Gelu.apply(*h);
    }
    matvec_ps_bias_into_wt(hidden, w_out, b_out, site.mu, out);
    recomputed
}

/// y = GELU(x·W_fc + b_fc)·W_out + b_out into reusable `hidden`/`out`
/// buffers (resized as needed; allocation-free once warm except the
/// selection mask when a finite-τ site is active).
///
/// `site` selects the arithmetic: the reference site runs the vectorized
/// FP32 matmuls; otherwise every row goes through [`mlp_row_into`]'s PS(μ)
/// + LAMP-repair kernel with per-row `Random` streams derived from `seed`
/// (the caller folds the layer index in first — see `forward::layer_seed`).
///
/// Returns the number of fc inner products recomputed in FP32.
#[allow(clippy::too_many_arguments)]
pub fn mlp_into(
    x: &Matrix,
    w_fc: &WeightTensor,
    b_fc: &[f32],
    w_out: &WeightTensor,
    b_out: &[f32],
    site: SitePrecision,
    seed: u64,
    hidden: &mut Matrix,
    out: &mut Matrix,
) -> Result<usize> {
    if x.cols() != w_fc.rows() || w_out.rows() != w_fc.cols() {
        return Err(Error::shape(format!(
            "mlp: x {:?} x w_fc {:?} x w_out {:?}",
            x.shape(),
            w_fc.shape(),
            w_out.shape()
        )));
    }
    if (!b_fc.is_empty() && b_fc.len() != w_fc.cols())
        || (!b_out.is_empty() && b_out.len() != w_out.cols())
    {
        return Err(Error::shape(format!(
            "mlp: bias lengths {}/{} vs widths {}/{}",
            b_fc.len(),
            b_out.len(),
            w_fc.cols(),
            w_out.cols()
        )));
    }
    if site.is_reference() {
        // The vectorized reference branch never reaches `mlp_row_into`,
        // so it carries its own site timer.
        let _t = crate::obs::timers::scoped(crate::obs::timers::Site::Mlp);
        matmul_bias_into_wt(x, w_fc, b_fc, hidden)?;
        for h in hidden.data_mut() {
            *h = Activation::Gelu.apply(*h);
        }
        matmul_bias_into_wt(hidden, w_out, b_out, out)?;
        return Ok(0);
    }
    let s = x.rows();
    hidden.resize(s, w_fc.cols());
    out.resize(s, w_out.cols());
    let mut recomputed = 0;
    for i in 0..s {
        recomputed += mlp_row_into(
            x.row(i),
            w_fc,
            b_fc,
            w_out,
            b_out,
            site,
            site_row_seed(seed, SITE_MLP, i),
            hidden.row_mut(i),
            out.row_mut(i),
        );
    }
    Ok(recomputed)
}

/// Allocating wrapper around [`mlp_into`] at the reference FP32 site:
/// seeds real-shape buffers up front and surfaces shape errors as a
/// `Result` instead of panicking.
pub fn mlp(
    x: &Matrix,
    w_fc: &WeightTensor,
    b_fc: &[f32],
    w_out: &WeightTensor,
    b_out: &[f32],
) -> Result<Matrix> {
    let mut hidden = Matrix::zeros(x.rows(), w_fc.cols());
    let mut out = Matrix::zeros(x.rows(), w_out.cols());
    mlp_into(
        x,
        w_fc,
        b_fc,
        w_out,
        b_out,
        SitePrecision::reference(),
        0,
        &mut hidden,
        &mut out,
    )?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lamp::softmax::SoftmaxRule;
    use crate::util::Rng;

    #[test]
    fn shapes() {
        let mut rng = Rng::new(1);
        let x = Matrix::randn(3, 8, 1.0, &mut rng);
        let w_fc: WeightTensor = Matrix::randn(8, 32, 0.1, &mut rng).into();
        let w_out: WeightTensor = Matrix::randn(32, 8, 0.1, &mut rng).into();
        let y = mlp(&x, &w_fc, &vec![0.0; 32], &w_out, &vec![0.0; 8]).unwrap();
        assert_eq!(y.shape(), (3, 8));
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let x = Matrix::zeros(2, 4);
        let w_fc: WeightTensor = Matrix::zeros(5, 16).into(); // 4 != 5
        let w_out: WeightTensor = Matrix::zeros(16, 4).into();
        assert!(mlp(&x, &w_fc, &[], &w_out, &[]).is_err());
        let w_fc: WeightTensor = Matrix::zeros(4, 16).into();
        let w_out_bad: WeightTensor = Matrix::zeros(8, 4).into(); // 16 != 8
        assert!(mlp(&x, &w_fc, &[], &w_out_bad, &[]).is_err());
        assert!(mlp(&x, &w_fc, &[0.0; 3], &w_out, &[]).is_err(), "bad bias length");
    }

    #[test]
    fn zero_weights_yield_bias() {
        let x = Matrix::zeros(2, 4);
        let w_fc: WeightTensor = Matrix::zeros(4, 16).into();
        let w_out: WeightTensor = Matrix::zeros(16, 4).into();
        let b_out = vec![1.5f32; 4];
        let y = mlp(&x, &w_fc, &vec![0.0; 16], &w_out, &b_out).unwrap();
        for i in 0..2 {
            for j in 0..4 {
                assert_eq!(y.get(i, j), 1.5);
            }
        }
    }

    #[test]
    fn gelu_nonlinearity_applied() {
        // One unit: x=1, w_fc=1, b=0 → GELU(1) ≈ 0.8412; w_out=1.
        let x = Matrix::from_vec(1, 1, vec![1.0]).unwrap();
        let w_fc: WeightTensor = Matrix::from_vec(1, 1, vec![1.0]).unwrap().into();
        let w_out: WeightTensor = Matrix::from_vec(1, 1, vec![1.0]).unwrap().into();
        let y = mlp(&x, &w_fc, &[0.0], &w_out, &[0.0]).unwrap();
        assert!((y.get(0, 0) - 0.8412).abs() < 1e-3, "{}", y.get(0, 0));
    }

    fn setup(s: usize) -> (Matrix, WeightTensor, Vec<f32>, WeightTensor, Vec<f32>) {
        let mut rng = Rng::new(5);
        let d = 8;
        let ff = 32;
        (
            Matrix::randn(s, d, 1.0, &mut rng),
            Matrix::randn(d, ff, 0.4, &mut rng).into(),
            (0..ff).map(|_| rng.normal_f32() * 0.1).collect(),
            Matrix::randn(ff, d, 0.4, &mut rng).into(),
            (0..d).map(|_| rng.normal_f32() * 0.1).collect(),
        )
    }

    #[test]
    fn batched_site_path_matches_row_kernel_bitwise() {
        let (x, w_fc, b_fc, w_out, b_out) = setup(5);
        for site in [
            SitePrecision::reference(),
            SitePrecision::uniform(3),
            SitePrecision::lamp(3, 0.5, SoftmaxRule::Strict),
            SitePrecision::lamp(3, 0.5, SoftmaxRule::Random),
        ] {
            let mut hidden = Matrix::zeros(0, 0);
            let mut out = Matrix::zeros(0, 0);
            let rec =
                mlp_into(&x, &w_fc, &b_fc, &w_out, &b_out, site, 9, &mut hidden, &mut out)
                    .unwrap();
            let mut rec_rows = 0;
            for i in 0..5 {
                let mut h = vec![0.0f32; 32];
                let mut o = vec![0.0f32; 8];
                rec_rows += mlp_row_into(
                    x.row(i),
                    &w_fc,
                    &b_fc,
                    &w_out,
                    &b_out,
                    site,
                    site_row_seed(9, SITE_MLP, i),
                    &mut h,
                    &mut o,
                );
                for (c, (&a, &b)) in out.row(i).iter().zip(&o).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "row {i} col {c}");
                }
            }
            assert_eq!(rec, rec_rows);
        }
    }

    #[test]
    fn lamp_repair_reduces_deviation_from_reference() {
        let (x, w_fc, b_fc, w_out, b_out) = setup(6);
        let run = |site: SitePrecision| -> (Matrix, usize) {
            let mut hidden = Matrix::zeros(0, 0);
            let mut out = Matrix::zeros(0, 0);
            let rec =
                mlp_into(&x, &w_fc, &b_fc, &w_out, &b_out, site, 3, &mut hidden, &mut out)
                    .unwrap();
            (out, rec)
        };
        let (reference, r0) = run(SitePrecision::reference());
        assert_eq!(r0, 0);
        let (uniform, ru) = run(SitePrecision::uniform(2));
        assert_eq!(ru, 0);
        let (lamp, rl) = run(SitePrecision::lamp(2, 0.0, SoftmaxRule::Strict));
        assert!(rl > 0, "tau=0 must recompute the sensitive units");
        let e_uni = uniform.max_abs_diff(&reference).unwrap();
        let e_lamp = lamp.max_abs_diff(&reference).unwrap();
        assert!(e_uni > 0.0, "PS(2) must perturb the MLP output");
        assert!(
            e_lamp < e_uni,
            "activation LAMP must reduce the deviation: lamp={e_lamp} uniform={e_uni}"
        );
    }
}
