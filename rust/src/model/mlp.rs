//! GPT-2 MLP block: fc → GELU → out, FP32.

use crate::error::Result;
use crate::lamp::activation::Activation;
use crate::linalg::matmul::matmul_bias_into;
use crate::linalg::Matrix;

/// y = GELU(x·W_fc + b_fc)·W_out + b_out into reusable `hidden`/`out`
/// buffers (resized as needed; allocation-free once warm).
///
/// FP32 path (not part of the simulated PS(μ) arithmetic) — uses the
/// vectorized matmul; see DESIGN.md §Perf.
pub fn mlp_into(
    x: &Matrix,
    w_fc: &Matrix,
    b_fc: &[f32],
    w_out: &Matrix,
    b_out: &[f32],
    hidden: &mut Matrix,
    out: &mut Matrix,
) -> Result<()> {
    debug_assert_eq!(w_fc.rows(), x.cols());
    debug_assert_eq!(w_out.shape(), (w_fc.cols(), x.cols()));
    matmul_bias_into(x, w_fc, b_fc, hidden)?;
    for h in hidden.data_mut() {
        *h = Activation::Gelu.apply(*h);
    }
    matmul_bias_into(hidden, w_out, b_out, out)
}

/// Allocating wrapper around [`mlp_into`].
pub fn mlp(
    x: &Matrix,
    w_fc: &Matrix,
    b_fc: &[f32],
    w_out: &Matrix,
    b_out: &[f32],
) -> Matrix {
    let mut hidden = Matrix::zeros(0, 0);
    let mut out = Matrix::zeros(0, 0);
    mlp_into(x, w_fc, b_fc, w_out, b_out, &mut hidden, &mut out).expect("mlp shapes");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn shapes() {
        let mut rng = Rng::new(1);
        let x = Matrix::randn(3, 8, 1.0, &mut rng);
        let w_fc = Matrix::randn(8, 32, 0.1, &mut rng);
        let w_out = Matrix::randn(32, 8, 0.1, &mut rng);
        let y = mlp(&x, &w_fc, &vec![0.0; 32], &w_out, &vec![0.0; 8]);
        assert_eq!(y.shape(), (3, 8));
    }

    #[test]
    fn zero_weights_yield_bias() {
        let x = Matrix::zeros(2, 4);
        let w_fc = Matrix::zeros(4, 16);
        let w_out = Matrix::zeros(16, 4);
        let b_out = vec![1.5f32; 4];
        let y = mlp(&x, &w_fc, &vec![0.0; 16], &w_out, &b_out);
        for i in 0..2 {
            for j in 0..4 {
                assert_eq!(y.get(i, j), 1.5);
            }
        }
    }

    #[test]
    fn gelu_nonlinearity_applied() {
        // One unit: x=1, w_fc=1, b=0 → GELU(1) ≈ 0.8412; w_out=1.
        let x = Matrix::from_vec(1, 1, vec![1.0]).unwrap();
        let w_fc = Matrix::from_vec(1, 1, vec![1.0]).unwrap();
        let w_out = Matrix::from_vec(1, 1, vec![1.0]).unwrap();
        let y = mlp(&x, &w_fc, &[0.0], &w_out, &[0.0]);
        assert!((y.get(0, 0) - 0.8412).abs() < 1e-3, "{}", y.get(0, 0));
    }
}
