//! The whole-model precision plan: one (μ, τ, rule) per composition site.
//!
//! The paper's claim is that look-ahead recomputation applies to *every*
//! composition f(g(x)) in a transformer — softmax ∘ matmul (§3.3),
//! entrywise activations ∘ matmul (§3.1), and normalization ∘ residual
//! (§3.2). A [`PrecisionPlan`] threads all of them through one value:
//!
//! * **attention** — per-(head, row) KQ scores accumulated in PS(μ), the
//!   softmax selection rule flags products for FP32 recomputation
//!   (`model::attention`, unchanged semantics).
//! * **mlp** — the fc and proj matmuls accumulate in PS(μ)
//!   ([`crate::linalg::matmul::matvec_ps_bias_into`]); the GELU
//!   sensitivity closed form (§3.1, [`crate::lamp::activation`]) flags
//!   hidden pre-activations whose fc inner products are recomputed in
//!   FP32 before the nonlinearity. The proj matmul has no downstream
//!   nonlinearity to guide a selection, so it runs uniform PS(μ).
//! * **norm** — the final residual row is stored in PS(μ) (simulated
//!   low-precision activation storage); the RMS-norm greedy solver
//!   (§3.2, [`crate::lamp::rmsnorm::select_rmsnorm`]) picks the
//!   components restored to full FP32 before the final layernorm. The
//!   RMS sensitivity is used as the selection surrogate for GPT-2's
//!   mean-subtracted layernorm — the κ_c it bounds is the RMS one.
//! * **sampler** — the tied-unembedding logit dots accumulate in PS(μ)
//!   ([`crate::softfloat::dot::dot_ps`] over the contiguous `wte` rows);
//!   the softmax selection rule applied to the logits row (the sampling
//!   distribution is a softmax) flags logits recomputed in FP32.
//!
//! A site whose precision [`is_reference`](SitePrecision::is_reference)
//! short-circuits to the exact pre-plan FP32 kernels, which is what makes
//! an all-reference plan reproduce the attention-only engine **bit for
//! bit** (enforced by `rust/tests/plan_parity.rs`).
//!
//! ## Decode parity
//!
//! Every site kernel is row-local and keys its `Random`-rule stream by
//! `(seed, site, position)` ([`site_row_seed`]) — functions of the request
//! and the position, never of the schedule — so KV-cache decode stays
//! bit-identical to the full forward pass under every plan (DESIGN.md
//! §Bit-exactness).

use super::attention::AttentionPrecision;
use crate::error::{Error, Result};
use crate::lamp::rmsnorm::select_rmsnorm;
use crate::lamp::softmax::{random_mask, select_softmax, SoftmaxRule};
use crate::linalg::matmul::{wt_row_dot_block, wt_row_dot_f32, wt_row_dot_ps};
use crate::linalg::simd::round_row_simd;
use crate::linalg::{WeightFormat, WeightTensor};
use crate::softfloat::round::round_to_mantissa;
use crate::util::Rng;

/// Per-site precision configuration — the same (μ, τ, rule) triple the
/// attention-only engine used, now one per composition site.
pub type SitePrecision = AttentionPrecision;

/// The plan's weight-storage requirement — the control-plane face of
/// [`WeightFormat`]. Compute sites describe *arithmetic* precision; this
/// field describes the *storage* precision of the parameters the request
/// expects to run against. Storage is an engine-level property (weights
/// are quantized once, at load), so the plan carries a requirement that
/// the engine checks at the front door (`Engine::validate_policy`,
/// `forward`), not a per-request conversion.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum WeightPrecision {
    /// Serve on whatever storage the engine holds (the default — every
    /// pre-existing plan and policy behaves this way).
    #[default]
    Any,
    /// Require the engine's weights to be stored in exactly this format.
    Exact(WeightFormat),
}

impl WeightPrecision {
    /// Does an engine holding `fmt`-storage weights satisfy this
    /// requirement?
    pub fn accepts(&self, fmt: WeightFormat) -> bool {
        match self {
            WeightPrecision::Any => true,
            WeightPrecision::Exact(want) => *want == fmt,
        }
    }

    /// Range validation (PrecisionPlan-style: typed error, front door).
    pub fn validate(&self) -> Result<()> {
        match self {
            WeightPrecision::Any => Ok(()),
            WeightPrecision::Exact(fmt) => fmt.validate(),
        }
    }

    /// Parse `any`, `f32`, `bf16`, or `ps<mu>`.
    pub fn by_name(name: &str) -> Result<Self> {
        if name == "any" {
            return Ok(WeightPrecision::Any);
        }
        Ok(WeightPrecision::Exact(WeightFormat::by_name(name)?))
    }

    /// Canonical name (inverse of [`Self::by_name`]).
    pub fn label(&self) -> String {
        match self {
            WeightPrecision::Any => "any".to_string(),
            WeightPrecision::Exact(fmt) => fmt.label(),
        }
    }
}

/// The plan's KV-cache storage requirement — the same `Any`/`Exact(fmt)`
/// control-plane shape as [`WeightPrecision`], applied to the engine's
/// paged KV-cache pool ([`crate::model::kvstore`]) instead of its weight
/// store. Like weight storage, the KV format is an engine-level property
/// (one pool, one slab format), so the plan carries a requirement checked
/// at the front door (`Engine::validate_policy`, `DecodeSession`), not a
/// per-request conversion.
pub type KvPrecision = WeightPrecision;

/// Self-speculative decoding configuration: the *draft* plan's per-site
/// precisions plus the number of look-ahead tokens drafted per round.
///
/// The enclosing [`PrecisionPlan`] stays the request's *target* plan — the
/// one every emitted token is verified (and the KV cache committed) under.
/// The draft sites only steer the throwaway look-ahead forward passes, so
/// they may be arbitrarily aggressive without touching output exactness;
/// [`PrecisionPlan::validate`] enforces that each draft site is no more
/// expensive than its target counterpart (and at least one strictly
/// cheaper), because a draft costlier than the target can never pay for
/// its verification.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecConfig {
    /// Draft attention-score site.
    pub attention: SitePrecision,
    /// Draft MLP site.
    pub mlp: SitePrecision,
    /// Draft final-norm site.
    pub norm: SitePrecision,
    /// Draft sampler site.
    pub sampler: SitePrecision,
    /// Look-ahead depth: tokens drafted per round (≥ 1). Each round
    /// verifies up to `k + 1` positions in one batched target-plan pass.
    pub k: usize,
}

impl SpecConfig {
    /// Draft uniformly at the same (μ, τ, rule) for every site.
    pub fn whole_model(site: SitePrecision, k: usize) -> Self {
        SpecConfig { attention: site, mlp: site, norm: site, sampler: site, k }
    }
}

/// Per-composition-site precision configuration for one forward pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrecisionPlan {
    /// Attention-score site (softmax ∘ KQ matmul), per layer and head.
    pub attention: SitePrecision,
    /// MLP site (GELU ∘ fc matmul; proj matmul uniform PS), per layer.
    pub mlp: SitePrecision,
    /// Final-norm site (layernorm ∘ residual storage), once per row.
    pub norm: SitePrecision,
    /// Sampler site (softmax ∘ logits matmul), once per row.
    pub sampler: SitePrecision,
    /// Weight-storage requirement ([`WeightPrecision::Any`] by default).
    pub weights: WeightPrecision,
    /// KV-cache storage requirement ([`KvPrecision::Any`] by default).
    pub kv: KvPrecision,
    /// Self-speculative decoding: draft plan + look-ahead depth
    /// (`None` = plain one-token-per-step decode).
    pub spec: Option<SpecConfig>,
}

impl PrecisionPlan {
    /// Full FP32 reference at every site — bit-identical to the
    /// pre-plan engine.
    pub fn reference() -> Self {
        PrecisionPlan {
            attention: SitePrecision::reference(),
            mlp: SitePrecision::reference(),
            norm: SitePrecision::reference(),
            sampler: SitePrecision::reference(),
            weights: WeightPrecision::Any,
            kv: KvPrecision::Any,
            spec: None,
        }
    }

    /// LAMP at the attention site only; every other site at reference —
    /// the exact semantics of the pre-plan `AttentionPrecision` knob.
    pub fn attention_only(attention: SitePrecision) -> Self {
        PrecisionPlan { attention, ..Self::reference() }
    }

    /// The same (μ, τ, rule) at every composition site.
    pub fn whole_model(site: SitePrecision) -> Self {
        PrecisionPlan {
            attention: site,
            mlp: site,
            norm: site,
            sampler: site,
            weights: WeightPrecision::Any,
            kv: KvPrecision::Any,
            spec: None,
        }
    }

    /// Replace the weight-storage requirement.
    pub fn with_weights(mut self, weights: WeightPrecision) -> Self {
        self.weights = weights;
        self
    }

    /// Replace the KV-cache storage requirement.
    pub fn with_kv(mut self, kv: KvPrecision) -> Self {
        self.kv = kv;
        self
    }

    /// Replace the MLP site.
    pub fn with_mlp(mut self, site: SitePrecision) -> Self {
        self.mlp = site;
        self
    }

    /// Replace the final-norm site.
    pub fn with_norm(mut self, site: SitePrecision) -> Self {
        self.norm = site;
        self
    }

    /// Replace the sampler site.
    pub fn with_sampler(mut self, site: SitePrecision) -> Self {
        self.sampler = site;
        self
    }

    /// Attach (or clear) the self-speculative decoding configuration.
    pub fn with_spec(mut self, spec: Option<SpecConfig>) -> Self {
        self.spec = spec;
        self
    }

    /// The plan the *draft* forward passes run under: the spec's per-site
    /// precisions with the storage requirements inherited from the engine
    /// the target already validated against (`Any` — there is one weight
    /// store and one KV pool; the draft reads the same ones) and no nested
    /// speculation. `None` when the plan is not speculative.
    pub fn draft_plan(&self) -> Option<PrecisionPlan> {
        self.spec.map(|s| PrecisionPlan {
            attention: s.attention,
            mlp: s.mlp,
            norm: s.norm,
            sampler: s.sampler,
            weights: WeightPrecision::Any,
            kv: KvPrecision::Any,
            spec: None,
        })
    }

    /// True when every non-attention site is at reference (the plan is
    /// expressible by the pre-plan attention knob).
    pub fn is_attention_only(&self) -> bool {
        self.mlp.is_reference() && self.norm.is_reference() && self.sampler.is_reference()
    }

    /// Validate every site's ranges — the single source of truth
    /// (`coordinator::PrecisionPolicy::validate` delegates here): μ ∈
    /// 1..=23, τ ≥ 0 and not NaN everywhere; for the softmax-composition
    /// sites (attention, sampler) the relaxed rules' relative threshold
    /// must additionally satisfy τ < 1 (mlp/norm thresholds are absolute
    /// sensitivities). Typed errors name the offending site so invalid
    /// plans are rejected at the front door instead of panicking
    /// downstream.
    pub fn validate(&self) -> Result<()> {
        for (name, site, relative_rules) in [
            ("attention", &self.attention, true),
            ("mlp", &self.mlp, false),
            ("norm", &self.norm, false),
            ("sampler", &self.sampler, true),
        ] {
            validate_site(site, name, relative_rules)?;
            // Length normalization (App. C.5) is defined over the causal
            // row lengths of attention; every other site sees fixed-width
            // rows (d_ff / d / vocab), where τ·√(ref_len/n) degenerates to
            // a constant rescale by an unrelated dimension. Reject early
            // rather than silently mis-scaling the user's τ.
            if name != "attention"
                && matches!(site.rule, SoftmaxRule::RelaxedLengthNorm { .. })
            {
                return Err(Error::config(format!(
                    "plan site {name}: the length-normalized rule applies to the \
                     attention site only"
                )));
            }
            // Tile granularity partitions a causal score row; every other
            // site is componentwise (d_ff / d / vocab entries with no
            // near-diagonal structure), so tile rules are attention-only.
            if name != "attention"
                && matches!(
                    site.rule,
                    SoftmaxRule::Tile { .. } | SoftmaxRule::TileRandom { .. }
                )
            {
                return Err(Error::config(format!(
                    "plan site {name}: tile rules apply to the attention site only"
                )));
            }
        }
        self.weights.validate()?;
        self.kv.validate()?;
        if let Some(spec) = &self.spec {
            self.validate_spec(spec)?;
        }
        Ok(())
    }

    /// Validate a speculative configuration against this (target) plan:
    /// the draft sites must pass the same range checks as plan sites, and
    /// the draft must be *cheaper* than the target — per site no more
    /// expensive (μ no larger, τ no smaller, any draft against a
    /// reference target), with at least one site strictly cheaper.
    /// Drafting at or above target cost can never pay for verification.
    fn validate_spec(&self, spec: &SpecConfig) -> Result<()> {
        if spec.k == 0 {
            return Err(Error::config(
                "spec: look-ahead depth k must be >= 1".to_string(),
            ));
        }
        let mut strictly_cheaper = false;
        for (name, draft, target, relative_rules) in [
            ("attention", &spec.attention, &self.attention, true),
            ("mlp", &spec.mlp, &self.mlp, false),
            ("norm", &spec.norm, &self.norm, false),
            ("sampler", &spec.sampler, &self.sampler, true),
        ] {
            let label = format!("spec draft {name}");
            validate_site(draft, &label, relative_rules)?;
            if name != "attention"
                && matches!(
                    draft.rule,
                    SoftmaxRule::RelaxedLengthNorm { .. }
                        | SoftmaxRule::Tile { .. }
                        | SoftmaxRule::TileRandom { .. }
                )
            {
                return Err(Error::config(format!(
                    "plan site {label}: length-normalized and tile rules apply \
                     to the attention site only"
                )));
            }
            if !target.is_reference() && (draft.mu > target.mu || draft.tau < target.tau)
            {
                return Err(Error::config(format!(
                    "spec draft {name}: draft site (mu={}, tau={}) is more \
                     expensive than the target site (mu={}, tau={}); drafts \
                     must not exceed target cost",
                    draft.mu, draft.tau, target.mu, target.tau
                )));
            }
            strictly_cheaper |= if target.is_reference() {
                !draft.is_reference()
            } else {
                draft.mu < target.mu || draft.tau > target.tau
            };
        }
        if !strictly_cheaper {
            return Err(Error::config(
                "spec: the draft plan must be strictly cheaper than the target \
                 plan at one or more sites"
                    .to_string(),
            ));
        }
        Ok(())
    }
}

/// Range checks for one site; `relative_rules` enables the τ < 1 check for
/// the softmax-style relaxed rules.
fn validate_site(site: &SitePrecision, name: &str, relative_rules: bool) -> Result<()> {
    if !(1..=23).contains(&site.mu) {
        return Err(Error::config(format!(
            "plan site {name}: mu {} out of 1..=23",
            site.mu
        )));
    }
    if site.tau.is_nan() {
        return Err(Error::config(format!(
            "plan site {name}: tau must not be NaN"
        )));
    }
    if site.tau < 0.0 {
        return Err(Error::config(format!(
            "plan site {name}: tau {} must be >= 0",
            site.tau
        )));
    }
    if relative_rules
        && matches!(
            site.rule,
            SoftmaxRule::Relaxed | SoftmaxRule::RelaxedLengthNorm { .. }
        )
        && site.tau.is_finite()
        && site.tau >= 1.0
    {
        return Err(Error::config(format!(
            "plan site {name}: relative threshold tau {} must be < 1 for relaxed rules",
            site.tau
        )));
    }
    if let SoftmaxRule::Tile { width } | SoftmaxRule::TileRandom { width } = site.rule {
        if width == 0 {
            return Err(Error::config(format!(
                "plan site {name}: tile width must be >= 1"
            )));
        }
    }
    Ok(())
}

impl Default for PrecisionPlan {
    fn default() -> Self {
        Self::reference()
    }
}

impl From<AttentionPrecision> for PrecisionPlan {
    /// The migration shim: anywhere the engine used to take the single
    /// attention knob, passing it now yields the attention-only plan.
    fn from(attention: AttentionPrecision) -> Self {
        PrecisionPlan::attention_only(attention)
    }
}

/// Site ids folded into the per-row RNG stream derivation. Attention keeps
/// its own `(seed, layer, head, row)` scheme (`attention::row_stream_seed`).
pub(crate) const SITE_MLP: u64 = 1;
pub(crate) const SITE_NORM: u64 = 2;
pub(crate) const SITE_SAMPLER: u64 = 3;

/// Derive the private RNG stream id for one (seed, site, row) triple —
/// the non-attention analogue of `attention::row_stream_seed`. The stream
/// depends only on the triple (for the MLP site the caller folds the layer
/// index into `seed` first via `forward::layer_seed`), so decode order and
/// thread scheduling cannot change a `Random`-rule selection. The
/// multipliers differ from every fold constant used by the attention
/// streams, keeping the site streams disjoint from the attention ones.
#[inline]
pub(crate) fn site_row_seed(seed: u64, site: u64, row: usize) -> u64 {
    seed ^ (site + 1).wrapping_mul(0xBF58476D1CE4E5B9)
        ^ (row as u64 + 1).wrapping_mul(0x94D049BB133111EB)
}

/// Apply the final-norm site to one residual row, in place.
///
/// Simulates PS(μ) storage of the norm input: every component is rounded
/// to μ mantissa bits, then the RMS-norm greedy solver (Prop 3.2) —
/// evaluated on the *rounded* values, the only ones available at run time
/// — selects the components restored to their exact FP32 values. `quant`
/// is caller-owned scratch (no allocation once warm). Returns the number
/// of restored components.
pub(crate) fn norm_site_row(
    x: &mut [f32],
    site: SitePrecision,
    row_seed: u64,
    quant: &mut Vec<f32>,
) -> usize {
    if site.is_reference() {
        return 0;
    }
    quant.clear();
    quant.resize(x.len(), 0.0);
    // Vectorized elementwise rounding when a backend is active
    // (bit-transparent — the lanewise kernel is the scalar op).
    if !round_row_simd(x, site.mu, quant) {
        for (q, &v) in quant.iter_mut().zip(x.iter()) {
            *q = round_to_mantissa(v, site.mu);
        }
    }
    if !site.tau.is_finite() {
        // Uniform low-precision storage, no look-ahead repair.
        x.copy_from_slice(quant);
        return 0;
    }
    let mask = if matches!(site.rule, SoftmaxRule::Random) {
        let count = select_rmsnorm(quant, site.tau as f64)
            .iter()
            .filter(|&&b| b)
            .count();
        random_mask(x.len(), count, &mut Rng::new(row_seed))
    } else {
        select_rmsnorm(quant, site.tau as f64)
    };
    let mut restored = 0;
    for (i, &keep_exact) in mask.iter().enumerate() {
        if keep_exact {
            restored += 1; // x[i] keeps its exact FP32 value
        } else {
            x[i] = quant[i];
        }
    }
    restored
}

/// Compute one logits row under the sampler site.
///
/// Reference: the pinned block-chain FP32 row dot of the tied unembedding
/// ([`wt_row_dot_block`]) — exactly the row body of
/// `matmul_transposed_into_wt`, so the reference short-circuit is
/// bit-identical to the batched unembedding path. Otherwise: PS(μ)
/// accumulation per logit ([`wt_row_dot_ps`] over the contiguous `wte`
/// rows), then the softmax selection rule over the logits row flags the
/// inner products recomputed with the sequential-FMA FP32 chain. All three
/// kernels dequantize the stored `wte` on the fly (exactly), so the site
/// behaves identically whether the weights live in f32, bf16, or PS(μ)
/// storage — only the *values* differ, by the one-time quantization
/// error. Returns the number of recomputed logits.
pub(crate) fn logits_row_site(
    x: &[f32],
    wte: &WeightTensor,
    site: SitePrecision,
    row_seed: u64,
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(out.len(), wte.rows());
    debug_assert_eq!(x.len(), wte.cols());
    if site.is_reference() {
        for (j, o) in out.iter_mut().enumerate() {
            *o = wt_row_dot_block(x, wte, j);
        }
        return 0;
    }
    for (j, o) in out.iter_mut().enumerate() {
        *o = wt_row_dot_ps(x, wte, j, site.mu);
    }
    let mut recomputed = 0;
    if site.tau.is_finite() {
        let mut rng = Rng::new(row_seed);
        let mask = select_softmax(out, site.tau, site.rule, &mut rng);
        for (j, &m) in mask.iter().enumerate() {
            if m {
                out[j] = wt_row_dot_f32(x, wte, j);
                recomputed += 1;
            }
        }
    }
    recomputed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lamp::softmax::SoftmaxRule;
    use crate::linalg::matmul::dot_block;
    use crate::linalg::Matrix;
    use crate::softfloat::dot::dot_f32;

    #[test]
    fn reference_plan_is_attention_only_and_valid() {
        let p = PrecisionPlan::reference();
        assert!(p.is_attention_only());
        p.validate().unwrap();
        let q = PrecisionPlan::attention_only(SitePrecision::lamp(
            4,
            0.1,
            SoftmaxRule::Strict,
        ));
        assert!(q.is_attention_only());
        assert!(!q.attention.is_reference());
    }

    #[test]
    fn from_attention_precision_is_attention_only() {
        let att = SitePrecision::lamp(3, 0.05, SoftmaxRule::Relaxed);
        let p: PrecisionPlan = att.into();
        assert_eq!(p.attention, att);
        assert!(p.mlp.is_reference());
        assert!(p.norm.is_reference());
        assert!(p.sampler.is_reference());
    }

    #[test]
    fn builders_and_whole_model() {
        let site = SitePrecision::lamp(7, 0.5, SoftmaxRule::Strict);
        let p = PrecisionPlan::whole_model(site);
        assert!(!p.is_attention_only());
        assert_eq!(p.mlp, site);
        let q = PrecisionPlan::reference().with_mlp(site);
        assert!(!q.is_attention_only());
        assert!(q.norm.is_reference() && q.sampler.is_reference());
        assert_eq!(q.with_norm(site).norm, site);
        assert_eq!(q.with_sampler(site).sampler, site);
    }

    #[test]
    fn validate_names_the_offending_site() {
        let bad_mu = PrecisionPlan::reference()
            .with_mlp(SitePrecision { mu: 0, tau: 0.1, rule: SoftmaxRule::Strict });
        let e = bad_mu.validate().unwrap_err().to_string();
        assert!(e.contains("mlp"), "{e}");
        let bad_nan = PrecisionPlan::reference()
            .with_norm(SitePrecision { mu: 4, tau: f32::NAN, rule: SoftmaxRule::Strict });
        let e = bad_nan.validate().unwrap_err().to_string();
        assert!(e.contains("norm") && e.contains("NaN"), "{e}");
        let bad_neg = PrecisionPlan::reference().with_sampler(SitePrecision {
            mu: 4,
            tau: -0.5,
            rule: SoftmaxRule::Strict,
        });
        let e = bad_neg.validate().unwrap_err().to_string();
        assert!(e.contains("sampler"), "{e}");
    }

    #[test]
    fn site_streams_distinct() {
        let mut seen = std::collections::HashSet::new();
        for site in [SITE_MLP, SITE_NORM, SITE_SAMPLER] {
            for row in 0..64 {
                assert!(seen.insert(site_row_seed(9, site, row)));
            }
        }
    }

    #[test]
    fn norm_site_reference_and_uniform() {
        let mut x = vec![1.5f32, -2.25, 0.75, 3.125];
        let orig = x.clone();
        let mut q = Vec::new();
        let n = norm_site_row(&mut x, SitePrecision::reference(), 1, &mut q);
        assert_eq!(n, 0);
        assert_eq!(x, orig, "reference must not touch the row");
        // Uniform PS(2): every component rounded, nothing restored.
        let n = norm_site_row(&mut x, SitePrecision::uniform(2), 1, &mut q);
        assert_eq!(n, 0);
        for (a, &b) in x.iter().zip(&orig) {
            assert_eq!(a.to_bits(), round_to_mantissa(b, 2).to_bits());
        }
    }

    #[test]
    fn norm_site_small_tau_restores_components() {
        let mut rng = Rng::new(4);
        let mut x: Vec<f32> = (0..32).map(|_| rng.normal_f32() * 2.0).collect();
        let orig = x.clone();
        let mut q = Vec::new();
        let site = SitePrecision::lamp(2, 0.05, SoftmaxRule::Strict);
        let n = norm_site_row(&mut x, site, 7, &mut q);
        assert!(n > 0, "tight tau on a spread vector must restore components");
        // Restored components are exact; the rest are the PS(2) roundings.
        let restored = x
            .iter()
            .zip(&orig)
            .filter(|(a, b)| a.to_bits() == b.to_bits())
            .count();
        assert!(restored >= n, "restored={restored} selected={n}");
    }

    #[test]
    fn norm_site_random_rule_matches_greedy_count_and_is_deterministic() {
        let mut rng = Rng::new(5);
        let x0: Vec<f32> = (0..24).map(|_| rng.normal_f32()).collect();
        let site = SitePrecision::lamp(3, 0.3, SoftmaxRule::Random);
        let strict = SitePrecision::lamp(3, 0.3, SoftmaxRule::Strict);
        let mut q = Vec::new();
        let mut a = x0.clone();
        let na = norm_site_row(&mut a, site, 11, &mut q);
        let mut b = x0.clone();
        let nb = norm_site_row(&mut b, site, 11, &mut q);
        assert_eq!(na, nb);
        assert_eq!(a, b, "same stream must reproduce exactly");
        let mut c = x0.clone();
        let nc = norm_site_row(&mut c, strict, 11, &mut q);
        assert_eq!(na, nc, "random is count-matched to the greedy solution");
    }

    #[test]
    fn logits_site_reference_matches_block_dot() {
        let mut rng = Rng::new(6);
        let m = Matrix::randn(16, 8, 1.0, &mut rng);
        let x: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
        // The reference short-circuit holds for every storage format: the
        // fused row dot equals the pinned dot_block chain over the
        // dequantized rows.
        for fmt in [WeightFormat::F32, WeightFormat::Bf16] {
            let wte = WeightTensor::from_matrix(&m, fmt).unwrap();
            let deq = wte.to_matrix();
            let mut out = vec![0.0f32; 16];
            let n = logits_row_site(&x, &wte, SitePrecision::reference(), 3, &mut out);
            assert_eq!(n, 0);
            for (j, &o) in out.iter().enumerate() {
                assert_eq!(o.to_bits(), dot_block(&x, deq.row(j)).to_bits());
            }
        }
    }

    #[test]
    fn logits_site_tau_zero_recovers_fp32_chain() {
        // τ=0 with the strict rule recomputes every nonzero-sensitivity
        // logit with the sequential FP32 chain.
        let mut rng = Rng::new(7);
        let m = Matrix::randn(32, 8, 1.0, &mut rng);
        let wte: WeightTensor = m.clone().into();
        let x: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
        let site = SitePrecision::lamp(2, 0.0, SoftmaxRule::Strict);
        let mut out = vec![0.0f32; 32];
        let n = logits_row_site(&x, &wte, site, 3, &mut out);
        assert!(n > 0);
        let mut uniform = vec![0.0f32; 32];
        let nu = logits_row_site(&x, &wte, SitePrecision::uniform(2), 3, &mut uniform);
        assert_eq!(nu, 0);
        let exact: Vec<f32> = (0..32).map(|j| dot_f32(&x, m.row(j))).collect();
        let err = |a: &[f32]| -> f32 {
            a.iter()
                .zip(&exact)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f32::max)
        };
        assert!(
            err(&out) < err(&uniform),
            "repair must beat uniform: {} vs {}",
            err(&out),
            err(&uniform)
        );
    }

    #[test]
    fn weight_precision_parse_label_accept() {
        assert_eq!(WeightPrecision::by_name("any").unwrap(), WeightPrecision::Any);
        assert_eq!(
            WeightPrecision::by_name("bf16").unwrap(),
            WeightPrecision::Exact(WeightFormat::Bf16)
        );
        assert_eq!(
            WeightPrecision::by_name("ps8").unwrap(),
            WeightPrecision::Exact(WeightFormat::PsRounded { mu: 8 })
        );
        assert!(WeightPrecision::by_name("ps99").is_err());
        for name in ["any", "f32", "bf16", "ps8"] {
            assert_eq!(WeightPrecision::by_name(name).unwrap().label(), name);
        }
        assert!(WeightPrecision::Any.accepts(WeightFormat::Bf16));
        assert!(WeightPrecision::Exact(WeightFormat::Bf16).accepts(WeightFormat::Bf16));
        assert!(!WeightPrecision::Exact(WeightFormat::Bf16).accepts(WeightFormat::F32));
    }

    #[test]
    fn plan_validates_kv_precision_and_default_is_any() {
        assert_eq!(PrecisionPlan::reference().kv, KvPrecision::Any);
        let p: PrecisionPlan = SitePrecision::uniform(4).into();
        assert_eq!(p.kv, KvPrecision::Any, "the From shim stays Any");
        let good =
            PrecisionPlan::reference().with_kv(KvPrecision::Exact(WeightFormat::Bf16));
        good.validate().unwrap();
        assert!(good.kv.accepts(WeightFormat::Bf16));
        assert!(!good.kv.accepts(WeightFormat::F32));
        let bad = PrecisionPlan::reference()
            .with_kv(KvPrecision::Exact(WeightFormat::PsRounded { mu: 77 }));
        assert!(bad.validate().is_err());
    }

    #[test]
    fn spec_validation_enforces_cheaper_draft() {
        let target = PrecisionPlan::whole_model(SitePrecision::lamp(
            4,
            0.1,
            SoftmaxRule::Relaxed,
        ));
        // Strictly cheaper at every site: coarser mantissa, looser tau.
        let good = target
            .with_spec(Some(SpecConfig::whole_model(SitePrecision::uniform(3), 2)));
        good.validate().unwrap();
        assert!(good.draft_plan().unwrap().spec.is_none(), "no nested spec");
        assert_eq!(good.draft_plan().unwrap().mlp, SitePrecision::uniform(3));
        // k = 0 rejected.
        let e = target
            .with_spec(Some(SpecConfig::whole_model(SitePrecision::uniform(3), 0)))
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("k"), "{e}");
        // Draft more expensive (finer mantissa) at one site rejected.
        let mut costly = SpecConfig::whole_model(SitePrecision::uniform(3), 2);
        costly.mlp = SitePrecision::lamp(8, 0.5, SoftmaxRule::Strict);
        let e = target.with_spec(Some(costly)).validate().unwrap_err().to_string();
        assert!(e.contains("mlp") && e.contains("expensive"), "{e}");
        // Draft tighter tau (more repair) rejected.
        let mut tight = SpecConfig::whole_model(SitePrecision::uniform(3), 2);
        tight.attention = SitePrecision::lamp(4, 0.01, SoftmaxRule::Relaxed);
        let e = target.with_spec(Some(tight)).validate().unwrap_err().to_string();
        assert!(e.contains("attention"), "{e}");
        // Draft == target everywhere: nothing strictly cheaper.
        let same = SpecConfig {
            attention: target.attention,
            mlp: target.mlp,
            norm: target.norm,
            sampler: target.sampler,
            k: 2,
        };
        let e = target.with_spec(Some(same)).validate().unwrap_err().to_string();
        assert!(e.contains("strictly cheaper"), "{e}");
        // Any draft is allowed against a reference target (and counts as
        // strictly cheaper as long as it is not itself reference).
        PrecisionPlan::reference()
            .with_spec(Some(SpecConfig::whole_model(SitePrecision::uniform(4), 3)))
            .validate()
            .unwrap();
        let e = PrecisionPlan::reference()
            .with_spec(Some(SpecConfig::whole_model(SitePrecision::reference(), 3)))
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("strictly cheaper"), "{e}");
        // Draft site ranges are validated like plan sites.
        let e = target
            .with_spec(Some(SpecConfig::whole_model(
                SitePrecision { mu: 0, tau: 0.5, rule: SoftmaxRule::Strict },
                2,
            )))
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("spec draft"), "{e}");
        // Tile rules stay attention-only inside the draft.
        let mut tiled = SpecConfig::whole_model(SitePrecision::uniform(3), 2);
        tiled.norm =
            SitePrecision::lamp(3, 2.0, SoftmaxRule::Tile { width: 4 });
        let e = target.with_spec(Some(tiled)).validate().unwrap_err().to_string();
        assert!(e.contains("attention site only"), "{e}");
    }

    #[test]
    fn plan_validates_weight_precision_and_default_is_any() {
        assert_eq!(PrecisionPlan::reference().weights, WeightPrecision::Any);
        let p: PrecisionPlan = SitePrecision::uniform(4).into();
        assert_eq!(p.weights, WeightPrecision::Any, "the From shim stays Any");
        let good = PrecisionPlan::reference()
            .with_weights(WeightPrecision::Exact(WeightFormat::Bf16));
        good.validate().unwrap();
        let bad = PrecisionPlan::reference()
            .with_weights(WeightPrecision::Exact(WeightFormat::PsRounded { mu: 0 }));
        assert!(bad.validate().is_err());
    }
}
