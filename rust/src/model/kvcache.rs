//! KV-cache incremental decoding over the paged block-pool subsystem.
//!
//! A [`DecodeSession`] carries the per-layer K/V projections of every
//! position it has already processed, so feeding one token costs one
//! embedding row, one row through each layer (QKV/proj/MLP row matvecs +
//! **O(S) new KQ inner products** against the cached keys) and one
//! unembedding row — O(S·d) per token instead of the O(S²·d) full
//! re-forward.
//!
//! ## Storage layout (PR 5 — `model::kvstore`)
//!
//! Cached rows no longer live in contiguous per-session `Matrix` buffers
//! sized for the full context window. The session holds a
//! [`PagedKvCache`]: a table of fixed-size blocks (`block_size` positions
//! × all layers × K and V) allocated lazily from a [`KvBlockPool`] shared
//! across the engine's sessions, so resident KV bytes track *live tokens*
//! and the pool's block capacity is the serving-level admission currency.
//! Blocks store rows in f32, bf16, or PS(μ) ([`kvstore::KvStore`]), with
//! the LAMP look-ahead repair pinning high-quantization-error rows at
//! exact f32 (see the `kvstore` module docs); a filled block on a sharing
//! pool is published under a `(seed, plan, token-prefix)` chain hash so
//! later sessions with a common prompt prefix adopt it instead of
//! recomputing ([`DecodeSession::adopt_prefix`]), copy-on-write
//! protecting mid-block boundaries.
//!
//! ## Bit-exactness contract (DESIGN.md §Bit-exactness, §Paged KV cache)
//!
//! The decode step runs the *same row kernels in the same order* as
//! [`forward`](super::forward::forward) runs them for the last row of a
//! full pass: `matvec_bias_into_wt` for the FP32 projections over the
//! stored weights, [`lamp_attention_row_kv`] for the scores (per-score
//! bit-identical to the contiguous [`lamp_attention_row`] shared with
//! `causal_attention_into` — each score is an independent accumulator
//! chain, so per-block runs change nothing), [`mlp_row_into`] for the MLP
//! site, `norm_site_row`/`logits_row_site` for the final-norm and sampler
//! sites, and the same `layernorm`/GELU scalars. Every site's
//! `Random`-rule stream for row `i` is keyed by `(seed, site/layer/head,
//! i)` — functions of the position only — so cached rows never need
//! re-selection. Consequently, with f32 KV storage the logits produced
//! incrementally are **bit-identical** to re-running the full forward
//! pass over the whole prefix, for every [`PrecisionPlan`] including
//! `Random` rules (verified by `rust/tests/decode_parity.rs` and
//! `rust/tests/plan_parity.rs`); quantized KV storage changes values by
//! exactly the storage error (and `repair_tau = 0` restores bit-equality
//! by pinning every inexact row).
//!
//! [`LampStats`] accounting is incremental: each decoded row adds its
//! `layers × heads × (pos + 1)` causal products once, so a session's
//! `rate()` is the recomputation rate over every product the session ever
//! evaluated — no double counting, unlike the re-forward loop which
//! re-evaluates (and re-counted) the whole triangle per token. Rows
//! adopted from the prefix-share index are never evaluated and therefore
//! never counted.
//!
//! [`lamp_attention_row`]: super::attention::lamp_attention_row
//! [`lamp_attention_row_kv`]: super::kvstore::lamp_attention_row_kv
//! [`KvBlockPool`]: super::kvstore::KvBlockPool
//! [`PagedKvCache`]: super::kvstore::PagedKvCache
//! [`kvstore`]: super::kvstore
//! [`kvstore::KvStore`]: super::kvstore::KvStore

use super::attention::{row_stream_seed, LampStats, RowLamp, SpecStats};
use super::config::ModelConfig;
use super::forward::layer_seed;
use super::kvstore::{
    chain_root, lamp_attention_row_kv, KvBlockPool, KvCheckpoint, PagedKvCache,
};
use super::layernorm::{layernorm, LN_EPS};
use super::mlp::mlp_row_into;
use super::plan::{
    logits_row_site, norm_site_row, site_row_seed, PrecisionPlan, SITE_MLP, SITE_NORM,
    SITE_SAMPLER,
};
use super::weights::Weights;
use crate::error::{Error, Result};
use crate::linalg::matmul::matvec_bias_into_wt;
use crate::util::ThreadPool;
use std::sync::Arc;
use std::time::Duration;

/// What a [`StepFaults`] hook decided for one decode step.
#[derive(Debug, Clone)]
pub enum StepFaultVerdict {
    /// Run the step normally.
    Proceed,
    /// Run the step normally after an artificial latency.
    Delay(Duration),
    /// Fail the step with this error *before any state changes* — the
    /// session stays consistent and the same token can be re-fed.
    Fail(Error),
    /// Poison the session permanently: this and every later step fail
    /// with a non-retryable error until `reset`/`reseat`.
    Poison(String),
}

/// Per-step fault hook consulted at the top of
/// [`DecodeSession::decode_step`], before any session state changes.
///
/// Implementations must be deterministic functions of the arguments —
/// `(session_seed, pos, attempt)` — so a chaos schedule replays exactly
/// from its seed regardless of thread timing. `attempt` counts the
/// consecutive injected failures already served at this position (0 on
/// the first try), letting a hook model transient faults that clear on
/// retry as well as multi-attempt faults that exhaust a retry budget.
pub trait StepFaults: Send + Sync {
    fn check(&self, session_seed: u64, pos: usize, attempt: u32) -> StepFaultVerdict;
}

/// Incremental decoding state bound to a model's weights.
///
/// All buffers — row scratch and the paged cache's block table — are
/// owned by the session; cache *blocks* come from the session's
/// [`KvBlockPool`] (a private single-session pool under
/// [`Self::new`], the engine's shared pool under [`Self::with_pool`]).
/// `decode_step` performs no heap allocation except block allocation at
/// block boundaries and the LAMP selection masks when a finite-τ site is
/// active.
pub struct DecodeSession<'w> {
    weights: &'w Weights,
    plan: PrecisionPlan,
    seed: u64,
    /// Number of positions already decoded (== next position index).
    pos: usize,
    /// Paged K/V storage; rows 0..pos are valid.
    kv: PagedKvCache,
    stats: LampStats,
    // Row scratch.
    x: Vec<f32>,
    xn: Vec<f32>,
    qkv: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    hidden: Vec<f32>,
    mlp: Vec<f32>,
    scores: Vec<f32>,
    /// Dequant-gather scratch for quantized/pinned cache runs.
    gather: Vec<f32>,
    normq: Vec<f32>,
    logits: Vec<f32>,
    /// Stats of the *draft* passes of speculative rounds (throwaway
    /// look-ahead work under the draft plan). Kept apart from `stats` so
    /// a speculative session's compute counters remain field-for-field
    /// comparable to solo non-speculative decode.
    draft_stats: LampStats,
    /// Logits of the last [`Self::verify_chunk`], row-major `[m, vocab]`.
    chunk_logits: Vec<f32>,
    /// Per-row target-plan stats of the last [`Self::verify_chunk`];
    /// `commit_round` merges the accepted rows into `stats` and drops the
    /// rest (solo decode would never have computed them).
    chunk_stats: Vec<LampStats>,
    /// Reusable per-row working state for the batched verify.
    spec_rows: Vec<SpecRow>,
    /// Optional worker pool for the batched verify fan-out; `None` (or a
    /// 1-thread pool) runs the sequential path, which is bit-identical.
    threads: Option<Arc<ThreadPool>>,
    /// Fault-injection hook (installed by `coordinator::faults`); `None`
    /// on real sessions. Survives `reset`/`reseat` — a recycled slot
    /// still belongs to the injector-wrapped engine that opened it.
    faults: Option<Arc<dyn StepFaults>>,
    /// Set once a `Poison` verdict fires; every later step fails
    /// non-retryably until `reset`/`reseat`.
    poisoned: Option<String>,
    /// Position of the last injected failure, with the count of
    /// consecutive injected failures served there (the `attempt` key).
    fault_pos: usize,
    fault_attempts: u32,
}

impl<'w> DecodeSession<'w> {
    /// Create a session backed by a private f32 block pool sized for the
    /// model's full context window — behaviorally identical to the
    /// historical contiguous cache. `prec` is a [`PrecisionPlan`] or
    /// anything convertible into one (a bare `AttentionPrecision` yields
    /// the attention-only plan).
    pub fn new(weights: &'w Weights, prec: impl Into<PrecisionPlan>, seed: u64) -> Self {
        let pool = KvBlockPool::private_for(&weights.config);
        Self::with_pool(weights, prec, seed, pool)
    }

    /// Create a session on a shared [`KvBlockPool`] — the serving
    /// configuration: blocks allocate lazily as the session grows, the
    /// pool's capacity gates admission, and (on sharing pools) filled
    /// blocks are published for prefix adoption.
    ///
    /// The pool must have been built for this model's configuration.
    pub fn with_pool(
        weights: &'w Weights,
        prec: impl Into<PrecisionPlan>,
        seed: u64,
        pool: Arc<KvBlockPool>,
    ) -> Self {
        let cfg = &weights.config;
        let d = cfg.d_model;
        let plan = prec.into();
        let root = chain_root(seed, &plan);
        DecodeSession {
            weights,
            plan,
            seed,
            pos: 0,
            kv: PagedKvCache::new(pool, root),
            stats: LampStats {
                recomputed: 0,
                causal_total: 0,
                per_layer: vec![0; cfg.layers],
                ..LampStats::default()
            },
            x: vec![0.0; d],
            xn: vec![0.0; d],
            qkv: vec![0.0; 3 * d],
            attn: vec![0.0; d],
            proj: vec![0.0; d],
            hidden: vec![0.0; cfg.d_ff()],
            mlp: vec![0.0; d],
            scores: Vec::with_capacity(cfg.seq),
            gather: Vec::new(),
            normq: Vec::with_capacity(d),
            logits: vec![0.0; cfg.vocab],
            draft_stats: LampStats::default(),
            chunk_logits: Vec::new(),
            chunk_stats: Vec::new(),
            spec_rows: Vec::new(),
            threads: None,
            faults: None,
            poisoned: None,
            fault_pos: 0,
            fault_attempts: 0,
        }
    }

    /// Install (or clear) a per-step fault hook. Serving code never calls
    /// this directly — `coordinator::faults::FaultInjector` installs its
    /// seeded hook on every session it opens.
    pub fn set_faults(&mut self, faults: Option<Arc<dyn StepFaults>>) {
        self.faults = faults;
    }

    /// Wire a worker pool into the batched speculative verify
    /// ([`Self::verify_chunk`] fans the candidate rows across it).
    /// Bit-identical to running without one: each row's computation is
    /// row-local and its RNG streams are keyed by position.
    pub fn set_threads(&mut self, threads: Option<Arc<ThreadPool>>) {
        self.threads = threads;
    }

    /// The session's effective precision plan (the *target* plan when the
    /// plan carries a speculative [`SpecConfig`](super::plan::SpecConfig)).
    pub fn plan(&self) -> &PrecisionPlan {
        &self.plan
    }

    /// Stats of speculative *draft* passes (look-ahead work under the
    /// draft plan, later re-verified or discarded). Always zero on
    /// non-speculative sessions; never mixed into [`Self::stats`].
    pub fn draft_stats(&self) -> &LampStats {
        &self.draft_stats
    }

    /// Model configuration.
    pub fn config(&self) -> &ModelConfig {
        &self.weights.config
    }

    /// Positions decoded so far.
    pub fn len(&self) -> usize {
        self.pos
    }

    /// True before the first token is fed.
    pub fn is_empty(&self) -> bool {
        self.pos == 0
    }

    /// Remaining context capacity.
    pub fn remaining(&self) -> usize {
        self.weights.config.seq - self.pos
    }

    /// The session's Random-rule / sampling seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The session's paged KV cache (block table, pinned-row accounting,
    /// resident bytes).
    pub fn kv(&self) -> &PagedKvCache {
        &self.kv
    }

    /// Accumulated LAMP statistics over every product this session has
    /// evaluated (each causal product counted exactly once; adopted
    /// prefix rows are never evaluated, hence never counted).
    pub fn stats(&self) -> &LampStats {
        &self.stats
    }

    /// Logits of the most recently decoded position ([vocab]).
    ///
    /// Meaningless (all zeros) before the first `decode_step`.
    pub fn logits(&self) -> &[f32] {
        &self.logits
    }

    /// Clear the cache (releasing every block to the pool) and the
    /// statistics, keeping the buffers. The logits buffer is zeroed so
    /// [`Self::logits`] honours its "all zeros before the first
    /// `decode_step`" contract — a recycled session must never leak the
    /// previous request's token distribution to a caller that samples
    /// before feeding anything.
    pub fn reset(&mut self) {
        self.pos = 0;
        self.poisoned = None;
        self.fault_pos = 0;
        self.fault_attempts = 0;
        self.kv.clear();
        self.stats = LampStats {
            recomputed: 0,
            causal_total: 0,
            per_layer: vec![0; self.weights.config.layers],
            ..LampStats::default()
        };
        self.draft_stats = LampStats::default();
        self.chunk_stats.clear();
        self.logits.iter_mut().for_each(|l| *l = 0.0);
    }

    /// Re-bind the session to a new precision plan and seed, clearing all
    /// cached state while keeping every buffer allocation — the slot-recycling
    /// primitive of the continuous-batching scheduler. A reseated session is
    /// bit-identical to a freshly constructed one: `pos` and the statistics
    /// are zeroed, every block returns to the pool, the share-chain root is
    /// re-keyed to the new `(seed, plan)`, and cache rows are always written
    /// before they are read (row `i` is stored by `decode_step` before
    /// attention over `0..=i`), so stale state from the previous request can
    /// never leak.
    pub fn reseat(&mut self, prec: impl Into<PrecisionPlan>, seed: u64) {
        self.plan = prec.into();
        self.seed = seed;
        self.kv.rebind(chain_root(seed, &self.plan));
        self.reset();
    }

    /// Adopt the longest shared prefix of `tokens` from the pool's
    /// prefix-share index (no-op on non-sharing pools or a non-empty
    /// session). Adopted positions are cached without being computed:
    /// their logits are never materialized and their products are never
    /// counted, so callers must keep at least the final prompt position
    /// out of the adopted range (pass `&prompt[..prompt.len() - 1]`) if
    /// they need its logits. Returns the number of positions adopted.
    pub fn adopt_prefix(&mut self, tokens: &[u32]) -> usize {
        if self.pos != 0 {
            return 0;
        }
        let adopted = self.kv.adopt_prefix(tokens);
        self.pos = adopted;
        adopted
    }

    /// Feed a whole prompt; afterwards [`Self::logits`] holds the last
    /// prompt position's logits. On a fresh session over a sharing pool,
    /// a cached common prefix (all but the last prompt token) is adopted
    /// instead of recomputed.
    pub fn prefill(&mut self, tokens: &[u32]) -> Result<()> {
        let start = if self.pos == 0 && tokens.len() > 1 {
            self.adopt_prefix(&tokens[..tokens.len() - 1])
        } else {
            0
        };
        for &t in &tokens[start..] {
            self.decode_step(t)?;
        }
        Ok(())
    }

    /// Feed `token` at the next position: updates the caches and computes
    /// that position's logits (available via [`Self::logits`]).
    ///
    /// On a shared pool this may allocate a block; exhaustion surfaces as
    /// the typed [`Error::Resource`] *before any state changes*, so the
    /// scheduler can preempt the session and recompute it later.
    pub fn decode_step(&mut self, token: u32) -> Result<()> {
        if let Some(msg) = &self.poisoned {
            return Err(Error::runtime(format!("session poisoned: {msg}")));
        }
        if let Some(hook) = &self.faults {
            let attempt = if self.fault_pos == self.pos { self.fault_attempts } else { 0 };
            match hook.check(self.seed, self.pos, attempt) {
                StepFaultVerdict::Proceed => {
                    self.fault_pos = self.pos;
                    self.fault_attempts = 0;
                }
                StepFaultVerdict::Delay(d) => {
                    std::thread::sleep(d);
                    self.fault_pos = self.pos;
                    self.fault_attempts = 0;
                }
                StepFaultVerdict::Fail(e) => {
                    self.fault_pos = self.pos;
                    self.fault_attempts = attempt + 1;
                    return Err(e);
                }
                StepFaultVerdict::Poison(msg) => {
                    let err = Error::runtime(format!("session poisoned: {msg}"));
                    self.poisoned = Some(msg);
                    return Err(err);
                }
            }
        }
        self.step_with(token, self.plan, false)
    }

    /// Route a step's stats to the committed or the draft accumulator.
    #[inline]
    fn sink(&mut self, draft: bool) -> &mut LampStats {
        if draft {
            &mut self.draft_stats
        } else {
            &mut self.stats
        }
    }

    /// The decode-step compute body, shared by the committed path
    /// ([`Self::decode_step`]: target plan, counters into
    /// [`Self::stats`]) and the speculative draft path
    /// ([`Self::draft_step`]: draft plan, counters into
    /// [`Self::draft_stats`]). Same kernels, same position-keyed seeds
    /// either way — a draft step differs only in the plan it runs and
    /// where its counters land. Draft steps skip the fault hook on
    /// purpose: verdicts are pure functions of `(seed, pos, attempt)`
    /// and the *verify* pass consults them for the same positions, so a
    /// draft consult would double-count delays without adding coverage.
    fn step_with(&mut self, token: u32, plan: PrecisionPlan, draft: bool) -> Result<()> {
        let weights = self.weights;
        let cfg = &weights.config;
        let d = cfg.d_model;
        let heads = cfg.heads;
        let hd = d / heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let d_ff = cfg.d_ff();
        let vocab = cfg.vocab;
        let i = self.pos;
        if i >= cfg.seq {
            return Err(Error::shape(format!(
                "decode_step: context full ({} positions)",
                cfg.seq
            )));
        }
        if token as usize >= vocab {
            return Err(Error::shape(format!("token {token} >= vocab {vocab}")));
        }
        // Same storage front doors as `forward` — a session constructed
        // around a storage-pinned plan on a mismatched engine must not
        // silently decode (DecodeSession::new/reseat cannot return Err,
        // so the gates live with the other per-step input checks).
        if !plan.weights.accepts(weights.weight_format()) {
            return Err(Error::config(format!(
                "plan requires {} weight storage, engine holds {}",
                plan.weights.label(),
                weights.weight_format().label()
            )));
        }
        if !plan.kv.accepts(self.kv.pool().format()) {
            return Err(Error::config(format!(
                "plan requires {} KV-cache storage, pool holds {}",
                plan.kv.label(),
                self.kv.pool().format().label()
            )));
        }

        // Embedding row: wte[token] + wpe[i], dequantized from storage
        // (exact; same single f32 add per element as the full pass).
        weights.wte.copy_row_into(token as usize, &mut self.x);
        weights.wpe.add_row_into(i, &mut self.x);

        for (l, blk) in weights.blocks.iter().enumerate() {
            // --- Attention sublayer (pre-LN), one row. ---
            self.xn.copy_from_slice(&self.x);
            layernorm(&mut self.xn, &blk.ln1_g, &blk.ln1_b, LN_EPS);
            matvec_bias_into_wt(&self.xn, &blk.w_qkv, &blk.b_qkv, &mut self.qkv);
            let (q_row, kv_row) = self.qkv.split_at(d);
            let (k_row, v_row) = kv_row.split_at(d);
            // Store this position's rows (quantizing + LAMP-repair pinning
            // per the pool's format) before attention reads rows 0..=i.
            self.kv.append_row(l, i, k_row, v_row)?;
            let lseed = layer_seed(self.seed, l);
            let mut row_lamp = RowLamp::default();
            for h in 0..heads {
                let off = h * hd;
                row_lamp.merge(lamp_attention_row_kv(
                    &q_row[off..off + hd],
                    &self.kv,
                    l,
                    off,
                    i + 1,
                    scale,
                    plan.attention,
                    row_stream_seed(lseed, h, i),
                    &mut self.scores,
                    &mut self.gather,
                    &mut self.attn[off..off + hd],
                ));
            }
            self.sink(draft).add_row(l, heads * (i + 1), row_lamp);
            // Output projection + residual.
            matvec_bias_into_wt(&self.attn, &blk.w_proj, &blk.b_proj, &mut self.proj);
            for c in 0..d {
                self.x[c] += self.proj[c];
            }

            // --- MLP sublayer (pre-LN), one row — the shared site kernel,
            // bit-identical to the full pass's row (DESIGN.md). ---
            self.xn.copy_from_slice(&self.x);
            layernorm(&mut self.xn, &blk.ln2_g, &blk.ln2_b, LN_EPS);
            let mlp_recomputed = mlp_row_into(
                &self.xn,
                &blk.w_fc,
                &blk.b_fc,
                &blk.w_out,
                &blk.b_out,
                plan.mlp,
                site_row_seed(lseed, SITE_MLP, i),
                &mut self.hidden,
                &mut self.mlp,
            );
            let sink = self.sink(draft);
            sink.mlp.recomputed += mlp_recomputed;
            sink.mlp.total += d_ff;
            for c in 0..d {
                self.x[c] += self.mlp[c];
            }
        }
        // Every layer's rows are stored: fold the token into the share
        // chain and publish the tail block if it just filled (drafts run
        // the cache in scratch mode, which suppresses publication).
        self.kv.complete_position(token, i);

        // Final-norm site (no-op at reference), then the final LN.
        if !plan.norm.is_reference() {
            let norm_recomputed = norm_site_row(
                &mut self.x,
                plan.norm,
                site_row_seed(self.seed, SITE_NORM, i),
                &mut self.normq,
            );
            self.sink(draft).norm.recomputed += norm_recomputed;
        }
        self.sink(draft).norm.total += d;
        layernorm(&mut self.x, &weights.lnf_g, &weights.lnf_b, LN_EPS);

        // Sampler site + tied unembedding row.
        let sampler_recomputed = logits_row_site(
            &self.x,
            &weights.wte,
            plan.sampler,
            site_row_seed(self.seed, SITE_SAMPLER, i),
            &mut self.logits,
        );
        let sink = self.sink(draft);
        sink.sampler.recomputed += sampler_recomputed;
        sink.sampler.total += vocab;
        self.pos = i + 1;
        Ok(())
    }

    // ---- Speculative decoding (DESIGN.md §Speculative decoding) ----
    //
    // One round: `spec_checkpoint` → `begin_draft` + k×`draft_step`
    // (scratch KV, draft plan) → `rollback` → `verify_chunk` (batched
    // target-plan forward over the candidates, staged KV) →
    // `commit_round` (accepted prefix) — leaving the session bit-identical
    // to having fed the accepted tokens through `decode_step` one by one.

    /// Snapshot the cache state at a round boundary (no staged rows).
    pub(crate) fn spec_checkpoint(&self) -> KvCheckpoint {
        self.kv.checkpoint()
    }

    /// Enter draft mode: subsequent appends run against a *scratch* KV
    /// extension — completed positions are never published to the pool's
    /// prefix-share index, so a later rollback cannot leave phantom
    /// entries behind.
    pub(crate) fn begin_draft(&mut self) {
        self.kv.set_scratch(true);
    }

    /// One look-ahead step under the (strictly cheaper) draft plan. The
    /// resulting logits approximate the target plan's; stats land in
    /// [`Self::draft_stats`]. No fault hook (see [`Self::step_with`]).
    pub(crate) fn draft_step(&mut self, token: u32, draft_plan: PrecisionPlan) -> Result<()> {
        self.step_with(token, draft_plan, true)
    }

    /// Discard everything after `cp` — the draft extension (any depth,
    /// even partially appended after a failed step) is truncated, its
    /// blocks return to the pool, and the session is bitwise back at the
    /// checkpoint.
    pub(crate) fn rollback(&mut self, cp: &KvCheckpoint) {
        self.kv.set_scratch(false);
        self.kv.truncate_to(cp);
        self.pos = cp.len();
    }

    /// Verify `cands` (the round's unfed base token plus the drafts) in
    /// one batched forward under the **target** plan: all rows' K/V are
    /// staged into the cache, every row's logits and stats are computed
    /// with the exact position-keyed kernels and seeds of
    /// [`Self::decode_step`], and nothing is committed — the caller walks
    /// the rows ([`Self::chunk_logits_row`]) and then calls
    /// [`Self::commit_round`] with the accepted prefix. On error the
    /// staged rows are released and the session is unchanged.
    ///
    /// With a worker pool installed ([`Self::set_threads`]) the rows fan
    /// out in parallel; each row only reads shared immutable state
    /// (weights, committed + previously staged K/V) and writes its own
    /// [`SpecRow`], so the parallel and sequential paths are
    /// bit-identical by construction.
    pub(crate) fn verify_chunk(&mut self, cands: &[u32]) -> Result<()> {
        if let Some(msg) = &self.poisoned {
            return Err(Error::runtime(format!("session poisoned: {msg}")));
        }
        if let Some(hook) = &self.faults {
            let hook = Arc::clone(hook);
            // Consult the hook for every candidate position up front, in
            // position order, before any state changes — the batched
            // analogue of decode_step's front door. Verdicts are pure
            // functions of (seed, pos, attempt), so a retry after a
            // `Fail` replays the same decision stream solo decode sees.
            for j in 0..cands.len() {
                let pos = self.pos + j;
                let attempt = if self.fault_pos == pos { self.fault_attempts } else { 0 };
                match hook.check(self.seed, pos, attempt) {
                    StepFaultVerdict::Proceed => {}
                    StepFaultVerdict::Delay(d) => std::thread::sleep(d),
                    StepFaultVerdict::Fail(e) => {
                        self.fault_pos = pos;
                        self.fault_attempts = attempt + 1;
                        return Err(e);
                    }
                    StepFaultVerdict::Poison(msg) => {
                        let err = Error::runtime(format!("session poisoned: {msg}"));
                        self.poisoned = Some(msg);
                        return Err(err);
                    }
                }
            }
            self.fault_pos = self.pos;
            self.fault_attempts = 0;
        }
        let mut rows = std::mem::take(&mut self.spec_rows);
        let result = self.verify_rows(cands, &mut rows);
        self.spec_rows = rows;
        if result.is_err() {
            self.kv.discard_staged();
        }
        result
    }

    /// The compute body of [`Self::verify_chunk`], with the row buffers
    /// moved out of `self` so the fan-out can borrow the cache and the
    /// rows independently.
    fn verify_rows(&mut self, cands: &[u32], rows: &mut Vec<SpecRow>) -> Result<()> {
        let weights = self.weights;
        let cfg = &weights.config;
        let d = cfg.d_model;
        let heads = cfg.heads;
        let hd = d / heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let d_ff = cfg.d_ff();
        let vocab = cfg.vocab;
        let layers = cfg.layers;
        let m = cands.len();
        let n = self.pos;
        if m == 0 {
            return Err(Error::invariant("verify_chunk: empty candidate chunk".to_string()));
        }
        if n + m > cfg.seq {
            return Err(Error::shape(format!(
                "verify_chunk: {m} candidates at position {n} exceed context {}",
                cfg.seq
            )));
        }
        for &t in cands {
            if t as usize >= vocab {
                return Err(Error::shape(format!("token {t} >= vocab {vocab}")));
            }
        }
        if !self.plan.weights.accepts(weights.weight_format()) {
            return Err(Error::config(format!(
                "plan requires {} weight storage, engine holds {}",
                self.plan.weights.label(),
                weights.weight_format().label()
            )));
        }
        if !self.plan.kv.accepts(self.kv.pool().format()) {
            return Err(Error::config(format!(
                "plan requires {} KV-cache storage, pool holds {}",
                self.plan.kv.label(),
                self.kv.pool().format().label()
            )));
        }
        while rows.len() < m {
            rows.push(SpecRow::new(cfg));
        }
        let rows = &mut rows[..m];
        let plan = self.plan;
        let seed = self.seed;
        let threads = self.threads.clone();
        let threads = threads.as_deref();

        // Embedding rows (sequential: trivial cost next to a layer).
        for (j, row) in rows.iter_mut().enumerate() {
            row.stats = LampStats {
                recomputed: 0,
                causal_total: 0,
                per_layer: vec![0; layers],
                ..LampStats::default()
            };
            weights.wte.copy_row_into(cands[j] as usize, &mut row.x);
            weights.wpe.add_row_into(n + j, &mut row.x);
        }

        for (l, blk) in weights.blocks.iter().enumerate() {
            let lseed = layer_seed(seed, l);
            // Pre-LN + QKV projection: row-local, fan out.
            run_rows(threads, rows, |_, row| {
                row.xn.copy_from_slice(&row.x);
                layernorm(&mut row.xn, &blk.ln1_g, &blk.ln1_b, LN_EPS);
                matvec_bias_into_wt(&row.xn, &blk.w_qkv, &blk.b_qkv, &mut row.qkv);
            });
            // Stage all m K/V rows of this layer (sequential: one shared
            // cache; the rows stay uncommitted until `commit_round`).
            for (j, row) in rows.iter_mut().enumerate() {
                let (_, kv_row) = row.qkv.split_at(d);
                let (k_row, v_row) = kv_row.split_at(d);
                self.kv.append_row(l, n + j, k_row, v_row)?;
            }
            // Attention + projection + residual + MLP: row-local once the
            // keys are staged. Row j attends to committed rows 0..n plus
            // staged rows n..=n+j — exactly the causal window solo decode
            // at position n+j would see.
            let kv = &self.kv;
            run_rows(threads, rows, |j, row| {
                let i = n + j;
                let (q_row, _) = row.qkv.split_at(d);
                let mut row_lamp = RowLamp::default();
                for h in 0..heads {
                    let off = h * hd;
                    row_lamp.merge(lamp_attention_row_kv(
                        &q_row[off..off + hd],
                        kv,
                        l,
                        off,
                        i + 1,
                        scale,
                        plan.attention,
                        row_stream_seed(lseed, h, i),
                        &mut row.scores,
                        &mut row.gather,
                        &mut row.attn[off..off + hd],
                    ));
                }
                row.stats.add_row(l, heads * (i + 1), row_lamp);
                matvec_bias_into_wt(&row.attn, &blk.w_proj, &blk.b_proj, &mut row.proj);
                for c in 0..d {
                    row.x[c] += row.proj[c];
                }
                row.xn.copy_from_slice(&row.x);
                layernorm(&mut row.xn, &blk.ln2_g, &blk.ln2_b, LN_EPS);
                let mlp_recomputed = mlp_row_into(
                    &row.xn,
                    &blk.w_fc,
                    &blk.b_fc,
                    &blk.w_out,
                    &blk.b_out,
                    plan.mlp,
                    site_row_seed(lseed, SITE_MLP, i),
                    &mut row.hidden,
                    &mut row.mlp,
                );
                row.stats.mlp.recomputed += mlp_recomputed;
                row.stats.mlp.total += d_ff;
                for c in 0..d {
                    row.x[c] += row.mlp[c];
                }
            });
        }

        // Final-norm site, final LN, sampler site — row-local.
        run_rows(threads, rows, |j, row| {
            let i = n + j;
            if !plan.norm.is_reference() {
                row.stats.norm.recomputed += norm_site_row(
                    &mut row.x,
                    plan.norm,
                    site_row_seed(seed, SITE_NORM, i),
                    &mut row.normq,
                );
            }
            row.stats.norm.total += d;
            layernorm(&mut row.x, &weights.lnf_g, &weights.lnf_b, LN_EPS);
            row.stats.sampler.recomputed += logits_row_site(
                &row.x,
                &weights.wte,
                plan.sampler,
                site_row_seed(seed, SITE_SAMPLER, i),
                &mut row.logits,
            );
            row.stats.sampler.total += vocab;
        });

        // Publish the per-row outputs for the acceptance walk.
        self.chunk_logits.resize(m * vocab, 0.0);
        self.chunk_stats.clear();
        for (j, row) in rows.iter_mut().enumerate() {
            self.chunk_logits[j * vocab..(j + 1) * vocab].copy_from_slice(&row.logits);
            self.chunk_stats.push(std::mem::take(&mut row.stats));
        }
        Ok(())
    }

    /// Logits row `j` of the last [`Self::verify_chunk`] (`[vocab]`).
    pub(crate) fn chunk_logits_row(&self, j: usize) -> &[f32] {
        let vocab = self.weights.config.vocab;
        &self.chunk_logits[j * vocab..(j + 1) * vocab]
    }

    /// Commit the accepted prefix of the last verified chunk:
    /// `accepted[j]` is the token fed at row `j`. Completes each accepted
    /// position in order (folding the share chain and publishing filled
    /// blocks exactly as committed decode does), releases the rejected
    /// rows' staged K/V, merges the accepted rows' target-plan stats into
    /// [`Self::stats`], and leaves [`Self::logits`] holding the last
    /// accepted row — bit-identical to having `decode_step`-fed
    /// `accepted` one token at a time.
    pub(crate) fn commit_round(&mut self, accepted: &[u32]) {
        debug_assert!(
            !accepted.is_empty() && accepted.len() <= self.chunk_stats.len(),
            "commit_round: accepted prefix out of range"
        );
        let vocab = self.weights.config.vocab;
        for (j, &t) in accepted.iter().enumerate() {
            self.kv.complete_position(t, self.pos + j);
            self.stats.merge(&self.chunk_stats[j]);
        }
        self.kv.discard_staged();
        let last = accepted.len() - 1;
        self.logits
            .copy_from_slice(&self.chunk_logits[last * vocab..(last + 1) * vocab]);
        self.pos += accepted.len();
    }

    /// Mutable access to the speculation counters (the sampler loop and
    /// the scheduler record rounds here).
    pub(crate) fn spec_stats_mut(&mut self) -> &mut SpecStats {
        &mut self.stats.spec
    }
}

/// Per-candidate working state for one batched speculative verify row —
/// the session's row scratch, owned per row so the chunk fans out across
/// the worker pool with zero shared mutable state.
struct SpecRow {
    x: Vec<f32>,
    xn: Vec<f32>,
    qkv: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    hidden: Vec<f32>,
    mlp: Vec<f32>,
    scores: Vec<f32>,
    gather: Vec<f32>,
    normq: Vec<f32>,
    logits: Vec<f32>,
    stats: LampStats,
}

impl SpecRow {
    fn new(cfg: &ModelConfig) -> Self {
        let d = cfg.d_model;
        SpecRow {
            x: vec![0.0; d],
            xn: vec![0.0; d],
            qkv: vec![0.0; 3 * d],
            attn: vec![0.0; d],
            proj: vec![0.0; d],
            hidden: vec![0.0; cfg.d_ff()],
            mlp: vec![0.0; d],
            scores: Vec::with_capacity(cfg.seq),
            gather: Vec::new(),
            normq: Vec::with_capacity(d),
            logits: vec![0.0; cfg.vocab],
            stats: LampStats::default(),
        }
    }
}

/// Raw base pointer into the verify rows, `Send`/`Sync` so the worker
/// closure can be shared across the pool; every job dereferences only
/// its own row index (the disjoint-writes idiom of attention's
/// `TileOut`).
#[derive(Clone, Copy)]
struct RowsPtr(*mut SpecRow);
unsafe impl Send for RowsPtr {}
unsafe impl Sync for RowsPtr {}

/// True when the current thread is itself a [`ThreadPool`] worker.
/// `scope_run` parks the submitting thread until its jobs drain, so a
/// nested fan-out from inside a worker can deadlock once every worker is
/// parked (the scheduler steps slots on a pool; a slot's verify must not
/// fan rows onto that same pool). Workers are all named by the pool, so
/// the guard is a name check.
fn on_pool_worker() -> bool {
    std::thread::current().name().is_some_and(|n| n.starts_with("lamp-worker"))
}

/// Run `f(j, &mut rows[j])` for every row — on the pool when one is
/// available, the chunk has more than one row, and the caller is not
/// already a pool worker; sequentially otherwise. Bit-identical either
/// way: each row reads only shared immutable state and writes only its
/// own `SpecRow`.
fn run_rows<F>(threads: Option<&ThreadPool>, rows: &mut [SpecRow], f: F)
where
    F: Fn(usize, &mut SpecRow) + Send + Sync,
{
    match threads {
        Some(pool) if pool.size() > 1 && rows.len() > 1 && !on_pool_worker() => {
            let base = RowsPtr(rows.as_mut_ptr());
            pool.scope_run(rows.len(), |j| {
                // SAFETY: jobs are indexed 0..rows.len(), each one
                // dereferences a distinct element, and `scope_run` joins
                // every job before returning — no aliasing, no escape.
                let row = unsafe { &mut *base.0.add(j) };
                f(j, row);
            });
        }
        _ => {
            for (j, row) in rows.iter_mut().enumerate() {
                f(j, row);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lamp::softmax::SoftmaxRule;
    use crate::linalg::WeightFormat;
    use crate::model::attention::AttentionPrecision;
    use crate::model::forward::forward;
    use crate::model::kvstore::KvCacheOptions;
    use crate::util::Rng;

    fn nano_weights(seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        Weights::random(&ModelConfig::nano(), &mut rng).unwrap()
    }

    fn plans() -> Vec<PrecisionPlan> {
        vec![
            AttentionPrecision::reference().into(),
            AttentionPrecision::uniform(3).into(),
            AttentionPrecision::lamp(3, 0.02, SoftmaxRule::Strict).into(),
            AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Relaxed).into(),
            AttentionPrecision::lamp(3, 0.05, SoftmaxRule::Random).into(),
            // Whole-model plans: every non-attention site active, both
            // deterministic and Random rules.
            PrecisionPlan::whole_model(AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Strict)),
            PrecisionPlan::attention_only(AttentionPrecision::lamp(
                3,
                0.05,
                SoftmaxRule::Random,
            ))
            .with_mlp(AttentionPrecision::lamp(4, 0.5, SoftmaxRule::Random))
            .with_norm(AttentionPrecision::lamp(4, 0.3, SoftmaxRule::Random))
            .with_sampler(AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Random)),
            PrecisionPlan::reference().with_norm(AttentionPrecision::uniform(4)),
        ]
    }

    #[test]
    fn incremental_logits_match_full_forward_bitwise() {
        // Every step's logits must equal the corresponding row of a full
        // forward pass over the same prefix — the KV cache's defining
        // property, now over the paged (f32) block store. Holds bitwise
        // for every plan and rule (all site streams are functions of
        // position, not of evaluation order).
        let w = nano_weights(1);
        let tokens: Vec<u32> = (0..14).map(|i| (i * 17 + 5) % 128).collect();
        for plan in plans() {
            let mut session = DecodeSession::new(&w, plan, 42);
            for (i, &t) in tokens.iter().enumerate() {
                session.decode_step(t).unwrap();
                let full = forward(&w, &tokens[..=i], plan, 42).unwrap();
                let want = full.logits.row(i);
                let got = session.logits();
                assert_eq!(got.len(), want.len());
                for (c, (a, b)) in got.iter().zip(want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "step {i} col {c} diverges under {plan:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn shared_pool_and_tiny_blocks_stay_bit_identical() {
        // Paging layout knobs (block size, shared pool, sharing on) must
        // never change logits: same plans, same bits as a private pool.
        let w = nano_weights(1);
        let cfg = &w.config;
        let tokens: Vec<u32> = (0..11).map(|i| (i * 23 + 9) % 128).collect();
        let pool = KvBlockPool::new(
            cfg,
            KvCacheOptions {
                format: WeightFormat::F32,
                repair_tau: f32::INFINITY,
                block_size: 3,
                capacity_blocks: 16,
                sharing: true,
            },
        )
        .unwrap();
        for plan in plans() {
            let mut paged = DecodeSession::with_pool(&w, plan, 42, pool.clone());
            let mut private = DecodeSession::new(&w, plan, 42);
            paged.prefill(&tokens).unwrap();
            private.prefill(&tokens).unwrap();
            for (a, b) in paged.logits().iter().zip(private.logits()) {
                assert_eq!(a.to_bits(), b.to_bits(), "block layout changed logits");
            }
            assert_eq!(paged.stats().recomputed, private.stats().recomputed);
        }
    }

    #[test]
    fn decode_matches_full_forward_under_quantized_storage() {
        // The KV-cache invariant carries over unchanged to quantized
        // *weight* storage: decode on bf16/PS weights is bit-identical to
        // the full forward pass on the same weights (shared fused-dequant
        // kernels).
        let w = nano_weights(8);
        let tokens: Vec<u32> = (0..10).map(|i| (i * 19 + 7) % 128).collect();
        for fmt in [WeightFormat::Bf16, WeightFormat::PsRounded { mu: 6 }] {
            let q = w.quantize_to(fmt).unwrap();
            for plan in [
                PrecisionPlan::reference(),
                PrecisionPlan::whole_model(AttentionPrecision::lamp(
                    3,
                    0.1,
                    SoftmaxRule::Strict,
                )),
            ] {
                let mut session = DecodeSession::new(&q, plan, 42);
                session.prefill(&tokens).unwrap();
                let full = forward(&q, &tokens, plan, 42).unwrap();
                let want = full.logits.row(tokens.len() - 1);
                for (c, (a, b)) in session.logits().iter().zip(want).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{fmt:?} col {c}");
                }
            }
        }
    }

    #[test]
    fn stats_count_each_product_once() {
        let w = nano_weights(2);
        let plan = PrecisionPlan::whole_model(AttentionPrecision::lamp(
            3,
            0.01,
            SoftmaxRule::Strict,
        ));
        let mut session = DecodeSession::new(&w, plan, 0);
        session.prefill(&[1, 2, 3, 4, 5]).unwrap();
        let cfg = &w.config;
        assert_eq!(session.len(), 5);
        assert_eq!(
            session.stats().causal_total,
            cfg.layers * cfg.heads * 5 * 6 / 2
        );
        assert!(session.stats().recomputed > 0);
        assert_eq!(session.stats().per_layer.len(), cfg.layers);
        let full = forward(&w, &[1, 2, 3, 4, 5], plan, 0).unwrap();
        // Same products evaluated once ⇒ identical counts to one full
        // pass, at every site.
        assert_eq!(session.stats().recomputed, full.stats.recomputed);
        assert_eq!(session.stats().per_layer, full.stats.per_layer);
        assert_eq!(session.stats().mlp, full.stats.mlp);
        assert_eq!(session.stats().norm, full.stats.norm);
        assert_eq!(session.stats().sampler, full.stats.sampler);
        assert_eq!(session.stats().mlp.total, cfg.layers * 5 * cfg.d_ff());
        assert_eq!(session.stats().sampler.total, 5 * cfg.vocab);
    }

    #[test]
    fn storage_pinned_plan_rejected_at_decode_step() {
        use crate::model::plan::WeightPrecision;
        let w = nano_weights(9);
        let pinned = PrecisionPlan::reference()
            .with_weights(WeightPrecision::Exact(WeightFormat::Bf16));
        // f32 weights + bf16-pinned plan: the session constructs (its
        // signature cannot fail) but refuses to decode — same front door
        // as `forward`.
        let mut session = DecodeSession::new(&w, pinned, 0);
        let err = session.decode_step(1).unwrap_err().to_string();
        assert!(err.contains("weight storage"), "{err}");
        // Matching storage decodes fine.
        let q = w.quantize_to(WeightFormat::Bf16).unwrap();
        let mut session = DecodeSession::new(&q, pinned, 0);
        session.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(session.len(), 3);
    }

    #[test]
    fn kv_pinned_plan_rejected_at_decode_step() {
        use crate::model::plan::KvPrecision;
        let w = nano_weights(9);
        // Private pools are f32: a bf16-KV-pinned plan must refuse to
        // decode, exactly like the weight-storage gate.
        let pinned =
            PrecisionPlan::reference().with_kv(KvPrecision::Exact(WeightFormat::Bf16));
        let mut session = DecodeSession::new(&w, pinned, 0);
        let err = session.decode_step(1).unwrap_err().to_string();
        assert!(err.contains("KV-cache storage"), "{err}");
        // A pool holding the pinned format decodes fine.
        let mut opts = KvCacheOptions::private(&w.config);
        opts.format = WeightFormat::Bf16;
        let pool = KvBlockPool::new(&w.config, opts).unwrap();
        let mut session = DecodeSession::with_pool(&w, pinned, 0, pool);
        session.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(session.len(), 3);
    }

    #[test]
    fn context_and_vocab_limits_enforced() {
        let w = nano_weights(3);
        let mut session = DecodeSession::new(&w, AttentionPrecision::reference(), 0);
        assert!(session.decode_step(9999).is_err());
        for t in 0..w.config.seq as u32 {
            session.decode_step(t % 128).unwrap();
        }
        assert_eq!(session.remaining(), 0);
        assert!(session.decode_step(1).is_err(), "context overflow must error");
    }

    #[test]
    fn reseat_bit_identical_to_fresh_session() {
        // The scheduler's slot-recycling contract: a reseated session must
        // reproduce a freshly constructed session bit-for-bit, for every
        // rule — including Random, whose streams depend on the new seed.
        let w = nano_weights(5);
        let tokens = [3u32, 7, 11, 2, 9];
        for prec_a in plans() {
            for prec_b in plans() {
                let mut recycled = DecodeSession::new(&w, prec_a, 1);
                recycled.prefill(&[8, 6, 4]).unwrap();
                recycled.reseat(prec_b, 77);
                assert!(recycled.is_empty());
                assert_eq!(recycled.stats().causal_total, 0);
                assert_eq!(recycled.kv().len(), 0, "reseat must release the cache");
                assert!(
                    recycled.logits().iter().all(|&l| l == 0.0),
                    "reseat must not leak the previous request's logits"
                );
                recycled.prefill(&tokens).unwrap();

                let mut fresh = DecodeSession::new(&w, prec_b, 77);
                fresh.prefill(&tokens).unwrap();
                for (a, b) in recycled.logits().iter().zip(fresh.logits()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "reseat leaked state");
                }
                assert_eq!(recycled.stats().recomputed, fresh.stats().recomputed);
                assert_eq!(recycled.stats().per_layer, fresh.stats().per_layer);
                assert_eq!(recycled.stats().mlp, fresh.stats().mlp);
                assert_eq!(recycled.stats().norm, fresh.stats().norm);
                assert_eq!(recycled.stats().sampler, fresh.stats().sampler);
            }
        }
    }

    #[test]
    fn prefill_adopts_shared_prefix_and_streams_stay_identical() {
        // Two sessions with the same (seed, plan) and a common prompt on a
        // sharing pool: the second adopts the first's published blocks,
        // skips their compute, and still produces bit-identical logits.
        let w = nano_weights(6);
        let cfg = &w.config;
        let pool = KvBlockPool::new(
            cfg,
            KvCacheOptions {
                format: WeightFormat::F32,
                repair_tau: f32::INFINITY,
                block_size: 4,
                capacity_blocks: 24,
                sharing: true,
            },
        )
        .unwrap();
        let tokens: Vec<u32> = (0..13).map(|i| (i * 7 + 2) % 128).collect();
        let plan: PrecisionPlan = AttentionPrecision::lamp(3, 0.05, SoftmaxRule::Random).into();

        let mut first = DecodeSession::with_pool(&w, plan, 11, pool.clone());
        first.prefill(&tokens).unwrap();
        let want: Vec<f32> = first.logits().to_vec();
        let full_products = first.stats().causal_total;
        drop(first); // blocks stay published in the pool's prompt cache

        let mut second = DecodeSession::with_pool(&w, plan, 11, pool.clone());
        second.prefill(&tokens).unwrap();
        assert!(second.kv().adopted() > 0, "second session must adopt the prefix");
        assert!(
            second.stats().causal_total < full_products,
            "adopted rows must not be recounted"
        );
        for (a, b) in second.logits().iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "prefix sharing changed logits");
        }

        // A different seed re-keys the chain: nothing is adopted.
        let mut other = DecodeSession::with_pool(&w, plan, 12, pool.clone());
        other.prefill(&tokens).unwrap();
        assert_eq!(other.kv().adopted(), 0);
    }

    #[test]
    fn reset_reuses_buffers() {
        let w = nano_weights(4);
        let prec = AttentionPrecision::reference();
        let mut session = DecodeSession::new(&w, prec, 7);
        session.prefill(&[1, 2, 3]).unwrap();
        let first: Vec<f32> = session.logits().to_vec();
        session.reset();
        assert!(session.is_empty());
        assert_eq!(session.stats().causal_total, 0);
        session.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(session.logits(), &first[..], "reset must be a clean slate");
    }

    #[test]
    fn verify_chunk_matches_sequential_decode_bitwise() {
        // The speculative verify contract: every chunk row's logits and
        // stats equal the sequential decode_step at the same position,
        // bitwise, for every plan (all site streams are position-keyed),
        // and a full commit leaves the session on the solo trajectory.
        let w = nano_weights(1);
        let cands = [9u32, 41, 77, 3];
        for plan in plans() {
            let mut solo = DecodeSession::new(&w, plan, 42);
            solo.prefill(&[5, 17, 29]).unwrap();
            let mut spec = DecodeSession::new(&w, plan, 42);
            spec.prefill(&[5, 17, 29]).unwrap();
            spec.verify_chunk(&cands).unwrap();
            for (j, &t) in cands.iter().enumerate() {
                solo.decode_step(t).unwrap();
                for (a, b) in spec.chunk_logits_row(j).iter().zip(solo.logits()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "row {j} under {plan:?}");
                }
            }
            spec.commit_round(&cands);
            assert_eq!(spec.len(), solo.len());
            for (a, b) in spec.logits().iter().zip(solo.logits()) {
                assert_eq!(a.to_bits(), b.to_bits(), "committed logits diverge");
            }
            assert_eq!(spec.stats().recomputed, solo.stats().recomputed);
            assert_eq!(spec.stats().causal_total, solo.stats().causal_total);
            assert_eq!(spec.stats().per_layer, solo.stats().per_layer);
            assert_eq!(spec.stats().mlp, solo.stats().mlp);
            assert_eq!(spec.stats().norm, solo.stats().norm);
            assert_eq!(spec.stats().sampler, solo.stats().sampler);
            // Continued decode after the commit stays on the trajectory.
            spec.decode_step(55).unwrap();
            solo.decode_step(55).unwrap();
            for (a, b) in spec.logits().iter().zip(solo.logits()) {
                assert_eq!(a.to_bits(), b.to_bits(), "post-commit step diverged");
            }
        }
    }

    #[test]
    fn partial_commit_matches_prefix_and_releases_rejected_rows() {
        let w = nano_weights(2);
        let plan =
            PrecisionPlan::whole_model(AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Random));
        let mut solo = DecodeSession::new(&w, plan, 7);
        solo.prefill(&[1, 2, 3]).unwrap();
        let mut spec = DecodeSession::new(&w, plan, 7);
        spec.prefill(&[1, 2, 3]).unwrap();
        spec.verify_chunk(&[10, 20, 30, 40]).unwrap();
        spec.commit_round(&[10, 20]); // reject rows 2 and 3
        solo.decode_step(10).unwrap();
        solo.decode_step(20).unwrap();
        assert_eq!(spec.len(), 5);
        assert_eq!(spec.kv().len(), 5);
        for (a, b) in spec.logits().iter().zip(solo.logits()) {
            assert_eq!(a.to_bits(), b.to_bits(), "partial commit diverged");
        }
        // Rejected rows' stats are dropped, not merged: single counting.
        assert_eq!(spec.stats().causal_total, solo.stats().causal_total);
        assert_eq!(spec.stats().sampler, solo.stats().sampler);
        // The rejected staged KV is gone; continued decode matches solo.
        spec.decode_step(99).unwrap();
        solo.decode_step(99).unwrap();
        for (a, b) in spec.logits().iter().zip(solo.logits()) {
            assert_eq!(a.to_bits(), b.to_bits(), "rejected rows leaked");
        }
    }

    #[test]
    fn draft_rollback_restores_bitwise_state() {
        let w = nano_weights(3);
        let plan: PrecisionPlan = AttentionPrecision::lamp(3, 0.05, SoftmaxRule::Random).into();
        let draft: PrecisionPlan = AttentionPrecision::uniform(2).into();
        let mut a = DecodeSession::new(&w, plan, 11);
        a.prefill(&[4, 8, 15]).unwrap();
        let cp = a.spec_checkpoint();
        a.begin_draft();
        a.draft_step(16, draft).unwrap();
        a.draft_step(23, draft).unwrap();
        assert_eq!(a.len(), 5);
        assert!(a.draft_stats().causal_total > 0, "draft work must be accounted");
        a.rollback(&cp);
        assert_eq!(a.len(), 3);
        // Draft work never touches the committed stats, and the next
        // committed step is bit-identical to a session that never drafted.
        let mut b = DecodeSession::new(&w, plan, 11);
        b.prefill(&[4, 8, 15]).unwrap();
        assert_eq!(a.stats().causal_total, b.stats().causal_total);
        assert_eq!(a.stats().sampler, b.stats().sampler);
        a.decode_step(42).unwrap();
        b.decode_step(42).unwrap();
        for (x, y) in a.logits().iter().zip(b.logits()) {
            assert_eq!(x.to_bits(), y.to_bits(), "rollback leaked draft state");
        }
        assert_eq!(a.kv().pool().stats().used_blocks, b.kv().pool().stats().used_blocks);
    }

    #[test]
    fn parallel_verify_is_bit_identical_to_sequential() {
        let w = nano_weights(4);
        let cands = [7u32, 7, 9, 100, 3];
        for plan in plans() {
            let mut seq_s = DecodeSession::new(&w, plan, 5);
            seq_s.prefill(&[2, 4, 6]).unwrap();
            seq_s.verify_chunk(&cands).unwrap();
            let mut par_s = DecodeSession::new(&w, plan, 5);
            par_s.set_threads(Some(Arc::new(ThreadPool::new(4))));
            par_s.prefill(&[2, 4, 6]).unwrap();
            par_s.verify_chunk(&cands).unwrap();
            for j in 0..cands.len() {
                for (a, b) in
                    par_s.chunk_logits_row(j).iter().zip(seq_s.chunk_logits_row(j))
                {
                    assert_eq!(a.to_bits(), b.to_bits(), "row {j} under {plan:?}");
                }
            }
            par_s.commit_round(&cands);
            seq_s.commit_round(&cands);
            assert_eq!(par_s.stats().recomputed, seq_s.stats().recomputed);
            assert_eq!(par_s.stats().per_layer, seq_s.stats().per_layer);
        }
    }

    #[test]
    fn verify_chunk_cleans_up_after_errors() {
        // A verify that fails (context overflow here) must release its
        // staged rows and leave the session usable.
        let w = nano_weights(5);
        let mut s = DecodeSession::new(&w, AttentionPrecision::reference(), 0);
        let prompt: Vec<u32> = (0..30).collect();
        s.prefill(&prompt).unwrap();
        let too_many: Vec<u32> = (0..8).collect();
        assert!(s.verify_chunk(&too_many).is_err(), "context overflow must error");
        assert_eq!(s.kv().len(), 30);
        s.decode_step(1).unwrap();
        assert_eq!(s.len(), 31);
        // Bad token mid-chunk: same cleanup.
        let mut s = DecodeSession::new(&w, AttentionPrecision::reference(), 0);
        s.prefill(&[1, 2]).unwrap();
        assert!(s.verify_chunk(&[3, 9999]).is_err());
        s.decode_step(3).unwrap();
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn pool_exhaustion_is_a_typed_resource_error() {
        // A pool smaller than the prompt fails mid-prefill with the
        // retryable resource error and the session can be reset and
        // resumed on a bigger pool path (the scheduler's preemption).
        let w = nano_weights(7);
        let pool = KvBlockPool::new(
            &w.config,
            KvCacheOptions {
                format: WeightFormat::F32,
                repair_tau: f32::INFINITY,
                block_size: 2,
                capacity_blocks: 2,
                sharing: false,
            },
        )
        .unwrap();
        let mut session =
            DecodeSession::with_pool(&w, AttentionPrecision::reference(), 0, pool.clone());
        let err = session.prefill(&[1, 2, 3, 4, 5, 6]).unwrap_err();
        assert!(err.is_resource(), "{err}");
        assert_eq!(session.len(), 4, "four positions fit in two 2-blocks");
        session.reset();
        assert_eq!(pool.stats().used_blocks, 0, "reset releases the blocks");
    }
}
