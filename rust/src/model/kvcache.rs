//! KV-cache incremental decoding over the paged block-pool subsystem.
//!
//! A [`DecodeSession`] carries the per-layer K/V projections of every
//! position it has already processed, so feeding one token costs one
//! embedding row, one row through each layer (QKV/proj/MLP row matvecs +
//! **O(S) new KQ inner products** against the cached keys) and one
//! unembedding row — O(S·d) per token instead of the O(S²·d) full
//! re-forward.
//!
//! ## Storage layout (PR 5 — `model::kvstore`)
//!
//! Cached rows no longer live in contiguous per-session `Matrix` buffers
//! sized for the full context window. The session holds a
//! [`PagedKvCache`]: a table of fixed-size blocks (`block_size` positions
//! × all layers × K and V) allocated lazily from a [`KvBlockPool`] shared
//! across the engine's sessions, so resident KV bytes track *live tokens*
//! and the pool's block capacity is the serving-level admission currency.
//! Blocks store rows in f32, bf16, or PS(μ) ([`kvstore::KvStore`]), with
//! the LAMP look-ahead repair pinning high-quantization-error rows at
//! exact f32 (see the `kvstore` module docs); a filled block on a sharing
//! pool is published under a `(seed, plan, token-prefix)` chain hash so
//! later sessions with a common prompt prefix adopt it instead of
//! recomputing ([`DecodeSession::adopt_prefix`]), copy-on-write
//! protecting mid-block boundaries.
//!
//! ## Bit-exactness contract (DESIGN.md §Bit-exactness, §Paged KV cache)
//!
//! The decode step runs the *same row kernels in the same order* as
//! [`forward`](super::forward::forward) runs them for the last row of a
//! full pass: `matvec_bias_into_wt` for the FP32 projections over the
//! stored weights, [`lamp_attention_row_kv`] for the scores (per-score
//! bit-identical to the contiguous [`lamp_attention_row`] shared with
//! `causal_attention_into` — each score is an independent accumulator
//! chain, so per-block runs change nothing), [`mlp_row_into`] for the MLP
//! site, `norm_site_row`/`logits_row_site` for the final-norm and sampler
//! sites, and the same `layernorm`/GELU scalars. Every site's
//! `Random`-rule stream for row `i` is keyed by `(seed, site/layer/head,
//! i)` — functions of the position only — so cached rows never need
//! re-selection. Consequently, with f32 KV storage the logits produced
//! incrementally are **bit-identical** to re-running the full forward
//! pass over the whole prefix, for every [`PrecisionPlan`] including
//! `Random` rules (verified by `rust/tests/decode_parity.rs` and
//! `rust/tests/plan_parity.rs`); quantized KV storage changes values by
//! exactly the storage error (and `repair_tau = 0` restores bit-equality
//! by pinning every inexact row).
//!
//! [`LampStats`] accounting is incremental: each decoded row adds its
//! `layers × heads × (pos + 1)` causal products once, so a session's
//! `rate()` is the recomputation rate over every product the session ever
//! evaluated — no double counting, unlike the re-forward loop which
//! re-evaluates (and re-counted) the whole triangle per token. Rows
//! adopted from the prefix-share index are never evaluated and therefore
//! never counted.
//!
//! [`lamp_attention_row`]: super::attention::lamp_attention_row
//! [`lamp_attention_row_kv`]: super::kvstore::lamp_attention_row_kv
//! [`KvBlockPool`]: super::kvstore::KvBlockPool
//! [`PagedKvCache`]: super::kvstore::PagedKvCache
//! [`kvstore`]: super::kvstore
//! [`kvstore::KvStore`]: super::kvstore::KvStore

use super::attention::{row_stream_seed, LampStats, RowLamp};
use super::config::ModelConfig;
use super::forward::layer_seed;
use super::kvstore::{chain_root, lamp_attention_row_kv, KvBlockPool, PagedKvCache};
use super::layernorm::{layernorm, LN_EPS};
use super::mlp::mlp_row_into;
use super::plan::{
    logits_row_site, norm_site_row, site_row_seed, PrecisionPlan, SITE_MLP, SITE_NORM,
    SITE_SAMPLER,
};
use super::weights::Weights;
use crate::error::{Error, Result};
use crate::linalg::matmul::matvec_bias_into_wt;
use std::sync::Arc;
use std::time::Duration;

/// What a [`StepFaults`] hook decided for one decode step.
#[derive(Debug, Clone)]
pub enum StepFaultVerdict {
    /// Run the step normally.
    Proceed,
    /// Run the step normally after an artificial latency.
    Delay(Duration),
    /// Fail the step with this error *before any state changes* — the
    /// session stays consistent and the same token can be re-fed.
    Fail(Error),
    /// Poison the session permanently: this and every later step fail
    /// with a non-retryable error until `reset`/`reseat`.
    Poison(String),
}

/// Per-step fault hook consulted at the top of
/// [`DecodeSession::decode_step`], before any session state changes.
///
/// Implementations must be deterministic functions of the arguments —
/// `(session_seed, pos, attempt)` — so a chaos schedule replays exactly
/// from its seed regardless of thread timing. `attempt` counts the
/// consecutive injected failures already served at this position (0 on
/// the first try), letting a hook model transient faults that clear on
/// retry as well as multi-attempt faults that exhaust a retry budget.
pub trait StepFaults: Send + Sync {
    fn check(&self, session_seed: u64, pos: usize, attempt: u32) -> StepFaultVerdict;
}

/// Incremental decoding state bound to a model's weights.
///
/// All buffers — row scratch and the paged cache's block table — are
/// owned by the session; cache *blocks* come from the session's
/// [`KvBlockPool`] (a private single-session pool under
/// [`Self::new`], the engine's shared pool under [`Self::with_pool`]).
/// `decode_step` performs no heap allocation except block allocation at
/// block boundaries and the LAMP selection masks when a finite-τ site is
/// active.
pub struct DecodeSession<'w> {
    weights: &'w Weights,
    plan: PrecisionPlan,
    seed: u64,
    /// Number of positions already decoded (== next position index).
    pos: usize,
    /// Paged K/V storage; rows 0..pos are valid.
    kv: PagedKvCache,
    stats: LampStats,
    // Row scratch.
    x: Vec<f32>,
    xn: Vec<f32>,
    qkv: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    hidden: Vec<f32>,
    mlp: Vec<f32>,
    scores: Vec<f32>,
    /// Dequant-gather scratch for quantized/pinned cache runs.
    gather: Vec<f32>,
    normq: Vec<f32>,
    logits: Vec<f32>,
    /// Fault-injection hook (installed by `coordinator::faults`); `None`
    /// on real sessions. Survives `reset`/`reseat` — a recycled slot
    /// still belongs to the injector-wrapped engine that opened it.
    faults: Option<Arc<dyn StepFaults>>,
    /// Set once a `Poison` verdict fires; every later step fails
    /// non-retryably until `reset`/`reseat`.
    poisoned: Option<String>,
    /// Position of the last injected failure, with the count of
    /// consecutive injected failures served there (the `attempt` key).
    fault_pos: usize,
    fault_attempts: u32,
}

impl<'w> DecodeSession<'w> {
    /// Create a session backed by a private f32 block pool sized for the
    /// model's full context window — behaviorally identical to the
    /// historical contiguous cache. `prec` is a [`PrecisionPlan`] or
    /// anything convertible into one (a bare `AttentionPrecision` yields
    /// the attention-only plan).
    pub fn new(weights: &'w Weights, prec: impl Into<PrecisionPlan>, seed: u64) -> Self {
        let pool = KvBlockPool::private_for(&weights.config);
        Self::with_pool(weights, prec, seed, pool)
    }

    /// Create a session on a shared [`KvBlockPool`] — the serving
    /// configuration: blocks allocate lazily as the session grows, the
    /// pool's capacity gates admission, and (on sharing pools) filled
    /// blocks are published for prefix adoption.
    ///
    /// The pool must have been built for this model's configuration.
    pub fn with_pool(
        weights: &'w Weights,
        prec: impl Into<PrecisionPlan>,
        seed: u64,
        pool: Arc<KvBlockPool>,
    ) -> Self {
        let cfg = &weights.config;
        let d = cfg.d_model;
        let plan = prec.into();
        let root = chain_root(seed, &plan);
        DecodeSession {
            weights,
            plan,
            seed,
            pos: 0,
            kv: PagedKvCache::new(pool, root),
            stats: LampStats {
                recomputed: 0,
                causal_total: 0,
                per_layer: vec![0; cfg.layers],
                ..LampStats::default()
            },
            x: vec![0.0; d],
            xn: vec![0.0; d],
            qkv: vec![0.0; 3 * d],
            attn: vec![0.0; d],
            proj: vec![0.0; d],
            hidden: vec![0.0; cfg.d_ff()],
            mlp: vec![0.0; d],
            scores: Vec::with_capacity(cfg.seq),
            gather: Vec::new(),
            normq: Vec::with_capacity(d),
            logits: vec![0.0; cfg.vocab],
            faults: None,
            poisoned: None,
            fault_pos: 0,
            fault_attempts: 0,
        }
    }

    /// Install (or clear) a per-step fault hook. Serving code never calls
    /// this directly — `coordinator::faults::FaultInjector` installs its
    /// seeded hook on every session it opens.
    pub fn set_faults(&mut self, faults: Option<Arc<dyn StepFaults>>) {
        self.faults = faults;
    }

    /// Model configuration.
    pub fn config(&self) -> &ModelConfig {
        &self.weights.config
    }

    /// Positions decoded so far.
    pub fn len(&self) -> usize {
        self.pos
    }

    /// True before the first token is fed.
    pub fn is_empty(&self) -> bool {
        self.pos == 0
    }

    /// Remaining context capacity.
    pub fn remaining(&self) -> usize {
        self.weights.config.seq - self.pos
    }

    /// The session's Random-rule / sampling seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The session's paged KV cache (block table, pinned-row accounting,
    /// resident bytes).
    pub fn kv(&self) -> &PagedKvCache {
        &self.kv
    }

    /// Accumulated LAMP statistics over every product this session has
    /// evaluated (each causal product counted exactly once; adopted
    /// prefix rows are never evaluated, hence never counted).
    pub fn stats(&self) -> &LampStats {
        &self.stats
    }

    /// Logits of the most recently decoded position ([vocab]).
    ///
    /// Meaningless (all zeros) before the first `decode_step`.
    pub fn logits(&self) -> &[f32] {
        &self.logits
    }

    /// Clear the cache (releasing every block to the pool) and the
    /// statistics, keeping the buffers. The logits buffer is zeroed so
    /// [`Self::logits`] honours its "all zeros before the first
    /// `decode_step`" contract — a recycled session must never leak the
    /// previous request's token distribution to a caller that samples
    /// before feeding anything.
    pub fn reset(&mut self) {
        self.pos = 0;
        self.poisoned = None;
        self.fault_pos = 0;
        self.fault_attempts = 0;
        self.kv.clear();
        self.stats = LampStats {
            recomputed: 0,
            causal_total: 0,
            per_layer: vec![0; self.weights.config.layers],
            ..LampStats::default()
        };
        self.logits.iter_mut().for_each(|l| *l = 0.0);
    }

    /// Re-bind the session to a new precision plan and seed, clearing all
    /// cached state while keeping every buffer allocation — the slot-recycling
    /// primitive of the continuous-batching scheduler. A reseated session is
    /// bit-identical to a freshly constructed one: `pos` and the statistics
    /// are zeroed, every block returns to the pool, the share-chain root is
    /// re-keyed to the new `(seed, plan)`, and cache rows are always written
    /// before they are read (row `i` is stored by `decode_step` before
    /// attention over `0..=i`), so stale state from the previous request can
    /// never leak.
    pub fn reseat(&mut self, prec: impl Into<PrecisionPlan>, seed: u64) {
        self.plan = prec.into();
        self.seed = seed;
        self.kv.rebind(chain_root(seed, &self.plan));
        self.reset();
    }

    /// Adopt the longest shared prefix of `tokens` from the pool's
    /// prefix-share index (no-op on non-sharing pools or a non-empty
    /// session). Adopted positions are cached without being computed:
    /// their logits are never materialized and their products are never
    /// counted, so callers must keep at least the final prompt position
    /// out of the adopted range (pass `&prompt[..prompt.len() - 1]`) if
    /// they need its logits. Returns the number of positions adopted.
    pub fn adopt_prefix(&mut self, tokens: &[u32]) -> usize {
        if self.pos != 0 {
            return 0;
        }
        let adopted = self.kv.adopt_prefix(tokens);
        self.pos = adopted;
        adopted
    }

    /// Feed a whole prompt; afterwards [`Self::logits`] holds the last
    /// prompt position's logits. On a fresh session over a sharing pool,
    /// a cached common prefix (all but the last prompt token) is adopted
    /// instead of recomputed.
    pub fn prefill(&mut self, tokens: &[u32]) -> Result<()> {
        let start = if self.pos == 0 && tokens.len() > 1 {
            self.adopt_prefix(&tokens[..tokens.len() - 1])
        } else {
            0
        };
        for &t in &tokens[start..] {
            self.decode_step(t)?;
        }
        Ok(())
    }

    /// Feed `token` at the next position: updates the caches and computes
    /// that position's logits (available via [`Self::logits`]).
    ///
    /// On a shared pool this may allocate a block; exhaustion surfaces as
    /// the typed [`Error::Resource`] *before any state changes*, so the
    /// scheduler can preempt the session and recompute it later.
    pub fn decode_step(&mut self, token: u32) -> Result<()> {
        if let Some(msg) = &self.poisoned {
            return Err(Error::runtime(format!("session poisoned: {msg}")));
        }
        if let Some(hook) = &self.faults {
            let attempt = if self.fault_pos == self.pos { self.fault_attempts } else { 0 };
            match hook.check(self.seed, self.pos, attempt) {
                StepFaultVerdict::Proceed => {
                    self.fault_pos = self.pos;
                    self.fault_attempts = 0;
                }
                StepFaultVerdict::Delay(d) => {
                    std::thread::sleep(d);
                    self.fault_pos = self.pos;
                    self.fault_attempts = 0;
                }
                StepFaultVerdict::Fail(e) => {
                    self.fault_pos = self.pos;
                    self.fault_attempts = attempt + 1;
                    return Err(e);
                }
                StepFaultVerdict::Poison(msg) => {
                    let err = Error::runtime(format!("session poisoned: {msg}"));
                    self.poisoned = Some(msg);
                    return Err(err);
                }
            }
        }
        let cfg = &self.weights.config;
        let d = cfg.d_model;
        let heads = cfg.heads;
        let hd = d / heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let i = self.pos;
        if i >= cfg.seq {
            return Err(Error::shape(format!(
                "decode_step: context full ({} positions)",
                cfg.seq
            )));
        }
        if token as usize >= cfg.vocab {
            return Err(Error::shape(format!(
                "token {token} >= vocab {}",
                cfg.vocab
            )));
        }
        // Same storage front doors as `forward` — a session constructed
        // around a storage-pinned plan on a mismatched engine must not
        // silently decode (DecodeSession::new/reseat cannot return Err,
        // so the gates live with the other per-step input checks).
        if !self.plan.weights.accepts(self.weights.weight_format()) {
            return Err(Error::config(format!(
                "plan requires {} weight storage, engine holds {}",
                self.plan.weights.label(),
                self.weights.weight_format().label()
            )));
        }
        if !self.plan.kv.accepts(self.kv.pool().format()) {
            return Err(Error::config(format!(
                "plan requires {} KV-cache storage, pool holds {}",
                self.plan.kv.label(),
                self.kv.pool().format().label()
            )));
        }

        // Embedding row: wte[token] + wpe[i], dequantized from storage
        // (exact; same single f32 add per element as the full pass).
        self.weights.wte.copy_row_into(token as usize, &mut self.x);
        self.weights.wpe.add_row_into(i, &mut self.x);

        for (l, blk) in self.weights.blocks.iter().enumerate() {
            // --- Attention sublayer (pre-LN), one row. ---
            self.xn.copy_from_slice(&self.x);
            layernorm(&mut self.xn, &blk.ln1_g, &blk.ln1_b, LN_EPS);
            matvec_bias_into_wt(&self.xn, &blk.w_qkv, &blk.b_qkv, &mut self.qkv);
            let (q_row, kv_row) = self.qkv.split_at(d);
            let (k_row, v_row) = kv_row.split_at(d);
            // Store this position's rows (quantizing + LAMP-repair pinning
            // per the pool's format) before attention reads rows 0..=i.
            self.kv.append_row(l, i, k_row, v_row)?;
            let lseed = layer_seed(self.seed, l);
            let mut row_lamp = RowLamp::default();
            for h in 0..heads {
                let off = h * hd;
                row_lamp.merge(lamp_attention_row_kv(
                    &q_row[off..off + hd],
                    &self.kv,
                    l,
                    off,
                    i + 1,
                    scale,
                    self.plan.attention,
                    row_stream_seed(lseed, h, i),
                    &mut self.scores,
                    &mut self.gather,
                    &mut self.attn[off..off + hd],
                ));
            }
            self.stats.add_row(l, heads * (i + 1), row_lamp);
            // Output projection + residual.
            matvec_bias_into_wt(&self.attn, &blk.w_proj, &blk.b_proj, &mut self.proj);
            for c in 0..d {
                self.x[c] += self.proj[c];
            }

            // --- MLP sublayer (pre-LN), one row — the shared site kernel,
            // bit-identical to the full pass's row (DESIGN.md). ---
            self.xn.copy_from_slice(&self.x);
            layernorm(&mut self.xn, &blk.ln2_g, &blk.ln2_b, LN_EPS);
            let mlp_recomputed = mlp_row_into(
                &self.xn,
                &blk.w_fc,
                &blk.b_fc,
                &blk.w_out,
                &blk.b_out,
                self.plan.mlp,
                site_row_seed(lseed, SITE_MLP, i),
                &mut self.hidden,
                &mut self.mlp,
            );
            self.stats.mlp.recomputed += mlp_recomputed;
            self.stats.mlp.total += cfg.d_ff();
            for c in 0..d {
                self.x[c] += self.mlp[c];
            }
        }
        // Every layer's rows are stored: fold the token into the share
        // chain and publish the tail block if it just filled.
        self.kv.complete_position(token, i);

        // Final-norm site (no-op at reference), then the final LN.
        if !self.plan.norm.is_reference() {
            self.stats.norm.recomputed += norm_site_row(
                &mut self.x,
                self.plan.norm,
                site_row_seed(self.seed, SITE_NORM, i),
                &mut self.normq,
            );
        }
        self.stats.norm.total += d;
        layernorm(&mut self.x, &self.weights.lnf_g, &self.weights.lnf_b, LN_EPS);

        // Sampler site + tied unembedding row.
        self.stats.sampler.recomputed += logits_row_site(
            &self.x,
            &self.weights.wte,
            self.plan.sampler,
            site_row_seed(self.seed, SITE_SAMPLER, i),
            &mut self.logits,
        );
        self.stats.sampler.total += cfg.vocab;
        self.pos = i + 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lamp::softmax::SoftmaxRule;
    use crate::linalg::WeightFormat;
    use crate::model::attention::AttentionPrecision;
    use crate::model::forward::forward;
    use crate::model::kvstore::KvCacheOptions;
    use crate::util::Rng;

    fn nano_weights(seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        Weights::random(&ModelConfig::nano(), &mut rng).unwrap()
    }

    fn plans() -> Vec<PrecisionPlan> {
        vec![
            AttentionPrecision::reference().into(),
            AttentionPrecision::uniform(3).into(),
            AttentionPrecision::lamp(3, 0.02, SoftmaxRule::Strict).into(),
            AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Relaxed).into(),
            AttentionPrecision::lamp(3, 0.05, SoftmaxRule::Random).into(),
            // Whole-model plans: every non-attention site active, both
            // deterministic and Random rules.
            PrecisionPlan::whole_model(AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Strict)),
            PrecisionPlan::attention_only(AttentionPrecision::lamp(
                3,
                0.05,
                SoftmaxRule::Random,
            ))
            .with_mlp(AttentionPrecision::lamp(4, 0.5, SoftmaxRule::Random))
            .with_norm(AttentionPrecision::lamp(4, 0.3, SoftmaxRule::Random))
            .with_sampler(AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Random)),
            PrecisionPlan::reference().with_norm(AttentionPrecision::uniform(4)),
        ]
    }

    #[test]
    fn incremental_logits_match_full_forward_bitwise() {
        // Every step's logits must equal the corresponding row of a full
        // forward pass over the same prefix — the KV cache's defining
        // property, now over the paged (f32) block store. Holds bitwise
        // for every plan and rule (all site streams are functions of
        // position, not of evaluation order).
        let w = nano_weights(1);
        let tokens: Vec<u32> = (0..14).map(|i| (i * 17 + 5) % 128).collect();
        for plan in plans() {
            let mut session = DecodeSession::new(&w, plan, 42);
            for (i, &t) in tokens.iter().enumerate() {
                session.decode_step(t).unwrap();
                let full = forward(&w, &tokens[..=i], plan, 42).unwrap();
                let want = full.logits.row(i);
                let got = session.logits();
                assert_eq!(got.len(), want.len());
                for (c, (a, b)) in got.iter().zip(want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "step {i} col {c} diverges under {plan:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn shared_pool_and_tiny_blocks_stay_bit_identical() {
        // Paging layout knobs (block size, shared pool, sharing on) must
        // never change logits: same plans, same bits as a private pool.
        let w = nano_weights(1);
        let cfg = &w.config;
        let tokens: Vec<u32> = (0..11).map(|i| (i * 23 + 9) % 128).collect();
        let pool = KvBlockPool::new(
            cfg,
            KvCacheOptions {
                format: WeightFormat::F32,
                repair_tau: f32::INFINITY,
                block_size: 3,
                capacity_blocks: 16,
                sharing: true,
            },
        )
        .unwrap();
        for plan in plans() {
            let mut paged = DecodeSession::with_pool(&w, plan, 42, pool.clone());
            let mut private = DecodeSession::new(&w, plan, 42);
            paged.prefill(&tokens).unwrap();
            private.prefill(&tokens).unwrap();
            for (a, b) in paged.logits().iter().zip(private.logits()) {
                assert_eq!(a.to_bits(), b.to_bits(), "block layout changed logits");
            }
            assert_eq!(paged.stats().recomputed, private.stats().recomputed);
        }
    }

    #[test]
    fn decode_matches_full_forward_under_quantized_storage() {
        // The KV-cache invariant carries over unchanged to quantized
        // *weight* storage: decode on bf16/PS weights is bit-identical to
        // the full forward pass on the same weights (shared fused-dequant
        // kernels).
        let w = nano_weights(8);
        let tokens: Vec<u32> = (0..10).map(|i| (i * 19 + 7) % 128).collect();
        for fmt in [WeightFormat::Bf16, WeightFormat::PsRounded { mu: 6 }] {
            let q = w.quantize_to(fmt).unwrap();
            for plan in [
                PrecisionPlan::reference(),
                PrecisionPlan::whole_model(AttentionPrecision::lamp(
                    3,
                    0.1,
                    SoftmaxRule::Strict,
                )),
            ] {
                let mut session = DecodeSession::new(&q, plan, 42);
                session.prefill(&tokens).unwrap();
                let full = forward(&q, &tokens, plan, 42).unwrap();
                let want = full.logits.row(tokens.len() - 1);
                for (c, (a, b)) in session.logits().iter().zip(want).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{fmt:?} col {c}");
                }
            }
        }
    }

    #[test]
    fn stats_count_each_product_once() {
        let w = nano_weights(2);
        let plan = PrecisionPlan::whole_model(AttentionPrecision::lamp(
            3,
            0.01,
            SoftmaxRule::Strict,
        ));
        let mut session = DecodeSession::new(&w, plan, 0);
        session.prefill(&[1, 2, 3, 4, 5]).unwrap();
        let cfg = &w.config;
        assert_eq!(session.len(), 5);
        assert_eq!(
            session.stats().causal_total,
            cfg.layers * cfg.heads * 5 * 6 / 2
        );
        assert!(session.stats().recomputed > 0);
        assert_eq!(session.stats().per_layer.len(), cfg.layers);
        let full = forward(&w, &[1, 2, 3, 4, 5], plan, 0).unwrap();
        // Same products evaluated once ⇒ identical counts to one full
        // pass, at every site.
        assert_eq!(session.stats().recomputed, full.stats.recomputed);
        assert_eq!(session.stats().per_layer, full.stats.per_layer);
        assert_eq!(session.stats().mlp, full.stats.mlp);
        assert_eq!(session.stats().norm, full.stats.norm);
        assert_eq!(session.stats().sampler, full.stats.sampler);
        assert_eq!(session.stats().mlp.total, cfg.layers * 5 * cfg.d_ff());
        assert_eq!(session.stats().sampler.total, 5 * cfg.vocab);
    }

    #[test]
    fn storage_pinned_plan_rejected_at_decode_step() {
        use crate::model::plan::WeightPrecision;
        let w = nano_weights(9);
        let pinned = PrecisionPlan::reference()
            .with_weights(WeightPrecision::Exact(WeightFormat::Bf16));
        // f32 weights + bf16-pinned plan: the session constructs (its
        // signature cannot fail) but refuses to decode — same front door
        // as `forward`.
        let mut session = DecodeSession::new(&w, pinned, 0);
        let err = session.decode_step(1).unwrap_err().to_string();
        assert!(err.contains("weight storage"), "{err}");
        // Matching storage decodes fine.
        let q = w.quantize_to(WeightFormat::Bf16).unwrap();
        let mut session = DecodeSession::new(&q, pinned, 0);
        session.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(session.len(), 3);
    }

    #[test]
    fn kv_pinned_plan_rejected_at_decode_step() {
        use crate::model::plan::KvPrecision;
        let w = nano_weights(9);
        // Private pools are f32: a bf16-KV-pinned plan must refuse to
        // decode, exactly like the weight-storage gate.
        let pinned =
            PrecisionPlan::reference().with_kv(KvPrecision::Exact(WeightFormat::Bf16));
        let mut session = DecodeSession::new(&w, pinned, 0);
        let err = session.decode_step(1).unwrap_err().to_string();
        assert!(err.contains("KV-cache storage"), "{err}");
        // A pool holding the pinned format decodes fine.
        let mut opts = KvCacheOptions::private(&w.config);
        opts.format = WeightFormat::Bf16;
        let pool = KvBlockPool::new(&w.config, opts).unwrap();
        let mut session = DecodeSession::with_pool(&w, pinned, 0, pool);
        session.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(session.len(), 3);
    }

    #[test]
    fn context_and_vocab_limits_enforced() {
        let w = nano_weights(3);
        let mut session = DecodeSession::new(&w, AttentionPrecision::reference(), 0);
        assert!(session.decode_step(9999).is_err());
        for t in 0..w.config.seq as u32 {
            session.decode_step(t % 128).unwrap();
        }
        assert_eq!(session.remaining(), 0);
        assert!(session.decode_step(1).is_err(), "context overflow must error");
    }

    #[test]
    fn reseat_bit_identical_to_fresh_session() {
        // The scheduler's slot-recycling contract: a reseated session must
        // reproduce a freshly constructed session bit-for-bit, for every
        // rule — including Random, whose streams depend on the new seed.
        let w = nano_weights(5);
        let tokens = [3u32, 7, 11, 2, 9];
        for prec_a in plans() {
            for prec_b in plans() {
                let mut recycled = DecodeSession::new(&w, prec_a, 1);
                recycled.prefill(&[8, 6, 4]).unwrap();
                recycled.reseat(prec_b, 77);
                assert!(recycled.is_empty());
                assert_eq!(recycled.stats().causal_total, 0);
                assert_eq!(recycled.kv().len(), 0, "reseat must release the cache");
                assert!(
                    recycled.logits().iter().all(|&l| l == 0.0),
                    "reseat must not leak the previous request's logits"
                );
                recycled.prefill(&tokens).unwrap();

                let mut fresh = DecodeSession::new(&w, prec_b, 77);
                fresh.prefill(&tokens).unwrap();
                for (a, b) in recycled.logits().iter().zip(fresh.logits()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "reseat leaked state");
                }
                assert_eq!(recycled.stats().recomputed, fresh.stats().recomputed);
                assert_eq!(recycled.stats().per_layer, fresh.stats().per_layer);
                assert_eq!(recycled.stats().mlp, fresh.stats().mlp);
                assert_eq!(recycled.stats().norm, fresh.stats().norm);
                assert_eq!(recycled.stats().sampler, fresh.stats().sampler);
            }
        }
    }

    #[test]
    fn prefill_adopts_shared_prefix_and_streams_stay_identical() {
        // Two sessions with the same (seed, plan) and a common prompt on a
        // sharing pool: the second adopts the first's published blocks,
        // skips their compute, and still produces bit-identical logits.
        let w = nano_weights(6);
        let cfg = &w.config;
        let pool = KvBlockPool::new(
            cfg,
            KvCacheOptions {
                format: WeightFormat::F32,
                repair_tau: f32::INFINITY,
                block_size: 4,
                capacity_blocks: 24,
                sharing: true,
            },
        )
        .unwrap();
        let tokens: Vec<u32> = (0..13).map(|i| (i * 7 + 2) % 128).collect();
        let plan: PrecisionPlan = AttentionPrecision::lamp(3, 0.05, SoftmaxRule::Random).into();

        let mut first = DecodeSession::with_pool(&w, plan, 11, pool.clone());
        first.prefill(&tokens).unwrap();
        let want: Vec<f32> = first.logits().to_vec();
        let full_products = first.stats().causal_total;
        drop(first); // blocks stay published in the pool's prompt cache

        let mut second = DecodeSession::with_pool(&w, plan, 11, pool.clone());
        second.prefill(&tokens).unwrap();
        assert!(second.kv().adopted() > 0, "second session must adopt the prefix");
        assert!(
            second.stats().causal_total < full_products,
            "adopted rows must not be recounted"
        );
        for (a, b) in second.logits().iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "prefix sharing changed logits");
        }

        // A different seed re-keys the chain: nothing is adopted.
        let mut other = DecodeSession::with_pool(&w, plan, 12, pool.clone());
        other.prefill(&tokens).unwrap();
        assert_eq!(other.kv().adopted(), 0);
    }

    #[test]
    fn reset_reuses_buffers() {
        let w = nano_weights(4);
        let prec = AttentionPrecision::reference();
        let mut session = DecodeSession::new(&w, prec, 7);
        session.prefill(&[1, 2, 3]).unwrap();
        let first: Vec<f32> = session.logits().to_vec();
        session.reset();
        assert!(session.is_empty());
        assert_eq!(session.stats().causal_total, 0);
        session.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(session.logits(), &first[..], "reset must be a clean slate");
    }

    #[test]
    fn pool_exhaustion_is_a_typed_resource_error() {
        // A pool smaller than the prompt fails mid-prefill with the
        // retryable resource error and the session can be reset and
        // resumed on a bigger pool path (the scheduler's preemption).
        let w = nano_weights(7);
        let pool = KvBlockPool::new(
            &w.config,
            KvCacheOptions {
                format: WeightFormat::F32,
                repair_tau: f32::INFINITY,
                block_size: 2,
                capacity_blocks: 2,
                sharing: false,
            },
        )
        .unwrap();
        let mut session =
            DecodeSession::with_pool(&w, AttentionPrecision::reference(), 0, pool.clone());
        let err = session.prefill(&[1, 2, 3, 4, 5, 6]).unwrap_err();
        assert!(err.is_resource(), "{err}");
        assert_eq!(session.len(), 4, "four positions fit in two 2-blocks");
        session.reset();
        assert_eq!(pool.stats().used_blocks, 0, "reset releases the blocks");
    }
}
