//! `.lamp` tensor container format — the interchange between the Python
//! compile path (which trains the models and serializes weights) and this
//! crate's **native engine** (`model::Weights` loads them directly; the
//! optional PJRT artifact path dequantizes to f32 before staging buffers).
//!
//! Two on-disk versions share one layout skeleton (little-endian):
//!
//! ```text
//! magic   : 8 bytes  b"LAMPTNSR"
//! version : u32      (1 or 2)
//! count   : u32      number of tensors
//! repeat count times:
//!   name_len : u32
//!   name     : name_len bytes UTF-8
//!   dtype    : u32    (0 = f32, 1 = i32, 2 = bf16, 3 = ps-f32)
//!   mu       : u32    — dtype 3 only: mantissa bits of the PS(μ) rounding
//!   ndim     : u32
//!   dims     : ndim × u64
//!   payload  : product(dims) × elem_bytes(dtype)
//! ```
//!
//! * **v1** carries f32/i32 tensors only (4 bytes/element) — the historical
//!   format. Readers keep accepting it unchanged, and the writer still
//!   emits v1 whenever every tensor is f32/i32, so files produced from
//!   f32-storage weights are byte-identical to the pre-v2 writer's.
//! * **v2** adds the mixed-precision weight-storage dtypes: `bf16`
//!   (2 bytes/element, the real memory saving) and `ps-f32` (f32 payload
//!   pre-rounded to μ mantissa bits, the storage-error simulation). Every
//!   stored value in either dtype is an exact f32, so decoding is
//!   error-free; `linalg::WeightTensor` consumes the payloads directly.
//!
//! The mirrored Python implementation lives in `python/compile/tensorio.py`.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"LAMPTNSR";
/// Legacy version: f32/i32 only. Still written when no tensor needs v2.
const VERSION_V1: u32 = 1;
/// Mixed-precision version: adds bf16 and ps-f32 dtypes.
const VERSION_V2: u32 = 2;

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    /// bfloat16 bit patterns, 2 bytes/element (v2 only).
    Bf16,
    /// f32 payload pre-rounded to `mu` mantissa bits (v2 only).
    PsF32 { mu: u32 },
}

impl DType {
    fn code(self) -> u32 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::Bf16 => 2,
            DType::PsF32 { .. } => 3,
        }
    }

    /// Bytes per stored element.
    pub fn elem_bytes(self) -> usize {
        match self {
            DType::Bf16 => 2,
            DType::F32 | DType::I32 | DType::PsF32 { .. } => 4,
        }
    }

    /// True for the dtypes the legacy v1 format can carry.
    fn v1_compatible(self) -> bool {
        matches!(self, DType::F32 | DType::I32)
    }
}

/// A named n-dimensional tensor (f32, i32, bf16, or ps-f32 payload).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    /// Raw little-endian payload, [`DType::elem_bytes`] bytes per element.
    pub raw: Vec<u8>,
}

impl Tensor {
    fn check_dims(name: &str, dims: &[usize], got: usize) -> Result<usize> {
        let n: usize = dims.iter().product();
        if n != got {
            return Err(Error::shape(format!(
                "tensor {name:?}: dims {dims:?} need {n} elements, got {got}"
            )));
        }
        Ok(n)
    }

    /// Build an f32 tensor.
    pub fn f32(name: impl Into<String>, dims: Vec<usize>, data: &[f32]) -> Result<Self> {
        let name = name.into();
        let n = Self::check_dims(&name, &dims, data.len())?;
        let mut raw = Vec::with_capacity(4 * n);
        for &x in data {
            raw.extend_from_slice(&x.to_le_bytes());
        }
        Ok(Tensor { name, dtype: DType::F32, dims, raw })
    }

    /// Build an i32 tensor.
    pub fn i32(name: impl Into<String>, dims: Vec<usize>, data: &[i32]) -> Result<Self> {
        let name = name.into();
        let n = Self::check_dims(&name, &dims, data.len())?;
        let mut raw = Vec::with_capacity(4 * n);
        for &x in data {
            raw.extend_from_slice(&x.to_le_bytes());
        }
        Ok(Tensor { name, dtype: DType::I32, dims, raw })
    }

    /// Build a bf16 tensor from raw bf16 bit patterns (v2 format).
    pub fn bf16(name: impl Into<String>, dims: Vec<usize>, data: &[u16]) -> Result<Self> {
        let name = name.into();
        let n = Self::check_dims(&name, &dims, data.len())?;
        let mut raw = Vec::with_capacity(2 * n);
        for &x in data {
            raw.extend_from_slice(&x.to_le_bytes());
        }
        Ok(Tensor { name, dtype: DType::Bf16, dims, raw })
    }

    /// Build a ps-f32 tensor: an f32 payload declared as PS(μ)-rounded
    /// (v2 format). The caller is responsible for the rounding;
    /// `linalg::WeightTensor::from_ps` re-rounds defensively on load.
    pub fn ps_f32(
        name: impl Into<String>,
        dims: Vec<usize>,
        mu: u32,
        data: &[f32],
    ) -> Result<Self> {
        if !(1..=23).contains(&mu) {
            return Err(Error::format(format!("ps-f32 tensor: mu {mu} out of 1..=23")));
        }
        let name = name.into();
        let n = Self::check_dims(&name, &dims, data.len())?;
        let mut raw = Vec::with_capacity(4 * n);
        for &x in data {
            raw.extend_from_slice(&x.to_le_bytes());
        }
        Ok(Tensor { name, dtype: DType::PsF32 { mu }, dims, raw })
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn f32_payload(&self) -> Vec<f32> {
        self.raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// Decode as f32 values (strict: the dtype must be exactly f32; use
    /// [`Self::dequant_f32`] to accept any float-like dtype).
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            return Err(Error::format(format!("tensor {:?} is not f32", self.name)));
        }
        Ok(self.f32_payload())
    }

    /// Decode as i32 values.
    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            return Err(Error::format(format!("tensor {:?} is not i32", self.name)));
        }
        Ok(self
            .raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decode as raw bf16 bit patterns.
    pub fn as_bf16(&self) -> Result<Vec<u16>> {
        if self.dtype != DType::Bf16 {
            return Err(Error::format(format!("tensor {:?} is not bf16", self.name)));
        }
        Ok(self
            .raw
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect())
    }

    /// Decode any float-like dtype to its exact f32 values (every bf16 /
    /// PS(μ)-rounded value is an exact f32, so this is lossless).
    pub fn dequant_f32(&self) -> Result<Vec<f32>> {
        match self.dtype {
            DType::F32 | DType::PsF32 { .. } => Ok(self.f32_payload()),
            DType::Bf16 => Ok(self
                .raw
                .chunks_exact(2)
                .map(|c| f32::from_bits((u16::from_le_bytes([c[0], c[1]]) as u32) << 16))
                .collect()),
            DType::I32 => Err(Error::format(format!(
                "tensor {:?} is i32, not a float dtype",
                self.name
            ))),
        }
    }
}

/// An ordered collection of named tensors.
#[derive(Debug, Clone, Default)]
pub struct TensorFile {
    tensors: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a tensor; names must be unique.
    pub fn push(&mut self, t: Tensor) -> Result<()> {
        if self.index.contains_key(&t.name) {
            return Err(Error::format(format!("duplicate tensor name {:?}", t.name)));
        }
        self.index.insert(t.name.clone(), self.tensors.len());
        self.tensors.push(t);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn require(&self, name: &str) -> Result<&Tensor> {
        self.get(name)
            .ok_or_else(|| Error::format(format!("missing tensor {name:?}")))
    }

    /// Tensors in insertion order.
    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// The minimal on-disk version able to carry every tensor: v1 when all
    /// dtypes are f32/i32 (byte-identical to the legacy writer), v2 once a
    /// mixed-precision dtype appears.
    pub fn required_version(&self) -> u32 {
        if self.tensors.iter().all(|t| t.dtype.v1_compatible()) {
            VERSION_V1
        } else {
            VERSION_V2
        }
    }

    /// Serialize to bytes (version chosen by [`Self::required_version`]).
    pub fn to_bytes(&self) -> Vec<u8> {
        let version = self.required_version();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            let name = t.name.as_bytes();
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name);
            out.extend_from_slice(&t.dtype.code().to_le_bytes());
            if let DType::PsF32 { mu } = t.dtype {
                out.extend_from_slice(&mu.to_le_bytes());
            }
            out.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
            for &d in &t.dims {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
            out.extend_from_slice(&t.raw);
        }
        out
    }

    /// Parse from bytes. Accepts both v1 (legacy, f32/i32 only) and v2
    /// (mixed-precision dtypes) — old files keep loading unchanged.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let mut cur = std::io::Cursor::new(data);
        let mut magic = [0u8; 8];
        cur.read_exact(&mut magic)
            .map_err(|_| Error::format("truncated .lamp file (magic)".to_string()))?;
        if &magic != MAGIC {
            return Err(Error::format("bad magic: not a .lamp file".to_string()));
        }
        let version = read_u32(&mut cur)?;
        if version != VERSION_V1 && version != VERSION_V2 {
            return Err(Error::format(format!("unsupported .lamp version {version}")));
        }
        let count = read_u32(&mut cur)? as usize;
        let mut file = TensorFile::new();
        for _ in 0..count {
            let name_len = read_u32(&mut cur)? as usize;
            if name_len > 4096 {
                return Err(Error::format(format!("tensor name too long: {name_len}")));
            }
            let mut name_buf = vec![0u8; name_len];
            cur.read_exact(&mut name_buf)
                .map_err(|_| Error::format("truncated name".to_string()))?;
            let name = String::from_utf8(name_buf)
                .map_err(|_| Error::format("non-UTF8 tensor name".to_string()))?;
            let code = read_u32(&mut cur)?;
            let dtype = match code {
                0 => DType::F32,
                1 => DType::I32,
                2 | 3 if version < VERSION_V2 => {
                    return Err(Error::format(format!(
                        "dtype code {code} requires .lamp v2, file is v{version}"
                    )));
                }
                2 => DType::Bf16,
                3 => {
                    let mu = read_u32(&mut cur)?;
                    if !(1..=23).contains(&mu) {
                        return Err(Error::format(format!(
                            "ps-f32 tensor {name:?}: mu {mu} out of 1..=23"
                        )));
                    }
                    DType::PsF32 { mu }
                }
                other => return Err(Error::format(format!("unknown dtype code {other}"))),
            };
            let ndim = read_u32(&mut cur)? as usize;
            if ndim > 16 {
                return Err(Error::format(format!("ndim too large: {ndim}")));
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u64(&mut cur)? as usize);
            }
            let n: usize = dims.iter().product();
            let nbytes = t_payload_bytes(dtype, n);
            let remaining = data.len() - cur.position() as usize;
            if nbytes > remaining {
                return Err(Error::format(format!(
                    "truncated payload for {name:?}: need {nbytes} bytes, {remaining} left"
                )));
            }
            let mut raw = vec![0u8; nbytes];
            cur.read_exact(&mut raw)
                .map_err(|_| Error::format("truncated payload".to_string()))?;
            file.push(Tensor { name, dtype, dims, raw })?;
        }
        Ok(file)
    }

    /// Write to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Read from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let data = std::fs::read(path.as_ref())?;
        Self::from_bytes(&data)
    }
}

fn t_payload_bytes(dtype: DType, n: usize) -> usize {
    dtype.elem_bytes() * n
}

fn read_u32(cur: &mut std::io::Cursor<&[u8]>) -> Result<u32> {
    let mut b = [0u8; 4];
    cur.read_exact(&mut b)
        .map_err(|_| Error::format("truncated u32".to_string()))?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(cur: &mut std::io::Cursor<&[u8]>) -> Result<u64> {
    let mut b = [0u8; 8];
    cur.read_exact(&mut b)
        .map_err(|_| Error::format("truncated u64".to_string()))?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bytes() {
        let mut file = TensorFile::new();
        file.push(Tensor::f32("w", vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap())
            .unwrap();
        file.push(Tensor::i32("tokens", vec![4], &[1, 2, 3, 4]).unwrap()).unwrap();
        let bytes = file.to_bytes();
        let back = TensorFile::from_bytes(&bytes).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.require("w").unwrap().as_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.require("tokens").unwrap().as_i32().unwrap(), vec![1, 2, 3, 4]);
        assert_eq!(back.require("w").unwrap().dims, vec![2, 3]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(TensorFile::from_bytes(b"NOTLAMP!....").is_err());
        assert!(TensorFile::from_bytes(b"").is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let mut file = TensorFile::new();
        file.push(Tensor::f32("w", vec![8], &[0.0; 8]).unwrap()).unwrap();
        let mut bytes = file.to_bytes();
        bytes.truncate(bytes.len() - 4);
        assert!(TensorFile::from_bytes(&bytes).is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut file = TensorFile::new();
        file.push(Tensor::f32("w", vec![1], &[0.0]).unwrap()).unwrap();
        assert!(file.push(Tensor::f32("w", vec![1], &[1.0]).unwrap()).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = Tensor::f32("x", vec![1], &[1.0]).unwrap();
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::f32("x", vec![2, 2], &[0.0; 3]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut file = TensorFile::new();
        file.push(Tensor::f32("a", vec![3], &[1.5, -2.5, 0.0]).unwrap()).unwrap();
        let path = std::env::temp_dir().join("lamp_tensorio_test.lamp");
        file.save(&path).unwrap();
        let back = TensorFile::load(&path).unwrap();
        assert_eq!(back.require("a").unwrap().as_f32().unwrap(), vec![1.5, -2.5, 0.0]);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn f32_only_files_stay_v1_byte_compatible() {
        // The legacy writer's exact bytes: version 1, dtype 0, no mu field.
        let mut file = TensorFile::new();
        file.push(Tensor::f32("w", vec![2], &[1.0, -2.0]).unwrap()).unwrap();
        assert_eq!(file.required_version(), 1);
        let bytes = file.to_bytes();
        assert_eq!(&bytes[8..12], &1u32.to_le_bytes());
        // Hand-assembled v1 bytes (the backward-compat read guarantee).
        let mut v1 = Vec::new();
        v1.extend_from_slice(b"LAMPTNSR");
        v1.extend_from_slice(&1u32.to_le_bytes()); // version
        v1.extend_from_slice(&1u32.to_le_bytes()); // count
        v1.extend_from_slice(&1u32.to_le_bytes()); // name_len
        v1.extend_from_slice(b"w");
        v1.extend_from_slice(&0u32.to_le_bytes()); // dtype f32
        v1.extend_from_slice(&1u32.to_le_bytes()); // ndim
        v1.extend_from_slice(&2u64.to_le_bytes()); // dims
        v1.extend_from_slice(&1.0f32.to_le_bytes());
        v1.extend_from_slice(&(-2.0f32).to_le_bytes());
        assert_eq!(bytes, v1, "f32-only writer output drifted from v1");
        let back = TensorFile::from_bytes(&v1).unwrap();
        assert_eq!(back.require("w").unwrap().as_f32().unwrap(), vec![1.0, -2.0]);
    }

    #[test]
    fn v2_roundtrip_bf16_and_ps() {
        let mut file = TensorFile::new();
        file.push(Tensor::bf16("wb", vec![2, 2], &[0x3F80, 0xBF80, 0x4000, 0x0000]).unwrap())
            .unwrap();
        file.push(Tensor::ps_f32("wp", vec![3], 6, &[1.5, -0.25, 3.0]).unwrap()).unwrap();
        file.push(Tensor::f32("bias", vec![2], &[0.5, 0.5]).unwrap()).unwrap();
        assert_eq!(file.required_version(), 2);
        let bytes = file.to_bytes();
        assert_eq!(&bytes[8..12], &2u32.to_le_bytes());
        let back = TensorFile::from_bytes(&bytes).unwrap();
        let wb = back.require("wb").unwrap();
        assert_eq!(wb.dtype, DType::Bf16);
        assert_eq!(wb.as_bf16().unwrap(), vec![0x3F80, 0xBF80, 0x4000, 0x0000]);
        assert_eq!(wb.dequant_f32().unwrap(), vec![1.0, -1.0, 2.0, 0.0]);
        assert!(wb.as_f32().is_err(), "strict as_f32 must reject bf16");
        let wp = back.require("wp").unwrap();
        assert_eq!(wp.dtype, DType::PsF32 { mu: 6 });
        assert_eq!(wp.dequant_f32().unwrap(), vec![1.5, -0.25, 3.0]);
        assert_eq!(back.require("bias").unwrap().as_f32().unwrap(), vec![0.5, 0.5]);
        assert!(back.require("bias").unwrap().dequant_f32().is_ok());
    }

    #[test]
    fn v1_rejects_v2_dtypes_and_bad_mu() {
        // A v1 file claiming a bf16 tensor is corrupt, not forward-compat.
        let mut bad = Vec::new();
        bad.extend_from_slice(b"LAMPTNSR");
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.extend_from_slice(b"w");
        bad.extend_from_slice(&2u32.to_le_bytes()); // bf16 in a v1 file
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.extend_from_slice(&1u64.to_le_bytes());
        bad.extend_from_slice(&[0u8; 2]);
        assert!(TensorFile::from_bytes(&bad).is_err());
        assert!(Tensor::ps_f32("w", vec![1], 0, &[0.0]).is_err());
        assert!(Tensor::ps_f32("w", vec![1], 24, &[0.0]).is_err());
        assert!(TensorFile::from_bytes(b"LAMPTNSR\x03\x00\x00\x00").is_err(), "version 3");
    }

    #[test]
    fn preserves_insertion_order() {
        let mut file = TensorFile::new();
        for name in ["z", "a", "m"] {
            file.push(Tensor::f32(name, vec![1], &[0.0]).unwrap()).unwrap();
        }
        let names: Vec<_> = file.tensors().iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, vec!["z", "a", "m"]);
        let back = TensorFile::from_bytes(&file.to_bytes()).unwrap();
        let names: Vec<_> = back.tensors().iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, vec!["z", "a", "m"]);
    }
}
