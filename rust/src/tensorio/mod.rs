//! `.lamp` tensor container format — the interchange between the Python
//! compile path (which trains the models and serializes weights) and the
//! Rust runtime (which feeds them to compiled HLO executables).
//!
//! Layout (little-endian):
//! ```text
//! magic   : 8 bytes  b"LAMPTNSR"
//! version : u32      (currently 1)
//! count   : u32      number of tensors
//! repeat count times:
//!   name_len : u32
//!   name     : name_len bytes UTF-8
//!   dtype    : u32    (0 = f32, 1 = i32)
//!   ndim     : u32
//!   dims     : ndim × u64
//!   payload  : product(dims) × 4 bytes
//! ```
//!
//! The mirrored Python writer lives in `python/compile/tensorio.py`.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"LAMPTNSR";
const VERSION: u32 = 1;

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn code(self) -> u32 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
        }
    }
    fn from_code(c: u32) -> Result<Self> {
        match c {
            0 => Ok(DType::F32),
            1 => Ok(DType::I32),
            other => Err(Error::format(format!("unknown dtype code {other}"))),
        }
    }
}

/// A named n-dimensional tensor (f32 or i32 payload).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    /// Raw little-endian payload, 4 bytes per element.
    pub raw: Vec<u8>,
}

impl Tensor {
    /// Build an f32 tensor.
    pub fn f32(name: impl Into<String>, dims: Vec<usize>, data: &[f32]) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error::shape(format!(
                "tensor {:?}: dims {:?} need {n} elements, got {}",
                name.into(),
                dims,
                data.len()
            )));
        }
        let mut raw = Vec::with_capacity(4 * n);
        for &x in data {
            raw.extend_from_slice(&x.to_le_bytes());
        }
        Ok(Tensor { name: name.into(), dtype: DType::F32, dims, raw })
    }

    /// Build an i32 tensor.
    pub fn i32(name: impl Into<String>, dims: Vec<usize>, data: &[i32]) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error::shape("tensor dims/data mismatch".to_string()));
        }
        let mut raw = Vec::with_capacity(4 * n);
        for &x in data {
            raw.extend_from_slice(&x.to_le_bytes());
        }
        Ok(Tensor { name: name.into(), dtype: DType::I32, dims, raw })
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode as f32 values.
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            return Err(Error::format(format!("tensor {:?} is not f32", self.name)));
        }
        Ok(self
            .raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decode as i32 values.
    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            return Err(Error::format(format!("tensor {:?} is not i32", self.name)));
        }
        Ok(self
            .raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// An ordered collection of named tensors.
#[derive(Debug, Clone, Default)]
pub struct TensorFile {
    tensors: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a tensor; names must be unique.
    pub fn push(&mut self, t: Tensor) -> Result<()> {
        if self.index.contains_key(&t.name) {
            return Err(Error::format(format!("duplicate tensor name {:?}", t.name)));
        }
        self.index.insert(t.name.clone(), self.tensors.len());
        self.tensors.push(t);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn require(&self, name: &str) -> Result<&Tensor> {
        self.get(name)
            .ok_or_else(|| Error::format(format!("missing tensor {name:?}")))
    }

    /// Tensors in insertion order.
    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            let name = t.name.as_bytes();
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name);
            out.extend_from_slice(&t.dtype.code().to_le_bytes());
            out.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
            for &d in &t.dims {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
            out.extend_from_slice(&t.raw);
        }
        out
    }

    /// Parse from bytes.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let mut cur = std::io::Cursor::new(data);
        let mut magic = [0u8; 8];
        cur.read_exact(&mut magic)
            .map_err(|_| Error::format("truncated .lamp file (magic)".to_string()))?;
        if &magic != MAGIC {
            return Err(Error::format("bad magic: not a .lamp file".to_string()));
        }
        let version = read_u32(&mut cur)?;
        if version != VERSION {
            return Err(Error::format(format!("unsupported .lamp version {version}")));
        }
        let count = read_u32(&mut cur)? as usize;
        let mut file = TensorFile::new();
        for _ in 0..count {
            let name_len = read_u32(&mut cur)? as usize;
            if name_len > 4096 {
                return Err(Error::format(format!("tensor name too long: {name_len}")));
            }
            let mut name_buf = vec![0u8; name_len];
            cur.read_exact(&mut name_buf)
                .map_err(|_| Error::format("truncated name".to_string()))?;
            let name = String::from_utf8(name_buf)
                .map_err(|_| Error::format("non-UTF8 tensor name".to_string()))?;
            let dtype = DType::from_code(read_u32(&mut cur)?)?;
            let ndim = read_u32(&mut cur)? as usize;
            if ndim > 16 {
                return Err(Error::format(format!("ndim too large: {ndim}")));
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u64(&mut cur)? as usize);
            }
            let n: usize = dims.iter().product();
            let remaining = data.len() - cur.position() as usize;
            if 4 * n > remaining {
                return Err(Error::format(format!(
                    "truncated payload for {name:?}: need {} bytes, {remaining} left",
                    4 * n
                )));
            }
            let mut raw = vec![0u8; 4 * n];
            cur.read_exact(&mut raw)
                .map_err(|_| Error::format("truncated payload".to_string()))?;
            file.push(Tensor { name, dtype, dims, raw })?;
        }
        Ok(file)
    }

    /// Write to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Read from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let data = std::fs::read(path.as_ref())?;
        Self::from_bytes(&data)
    }
}

fn read_u32(cur: &mut std::io::Cursor<&[u8]>) -> Result<u32> {
    let mut b = [0u8; 4];
    cur.read_exact(&mut b)
        .map_err(|_| Error::format("truncated u32".to_string()))?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(cur: &mut std::io::Cursor<&[u8]>) -> Result<u64> {
    let mut b = [0u8; 8];
    cur.read_exact(&mut b)
        .map_err(|_| Error::format("truncated u64".to_string()))?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bytes() {
        let mut file = TensorFile::new();
        file.push(Tensor::f32("w", vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap())
            .unwrap();
        file.push(Tensor::i32("tokens", vec![4], &[1, 2, 3, 4]).unwrap()).unwrap();
        let bytes = file.to_bytes();
        let back = TensorFile::from_bytes(&bytes).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.require("w").unwrap().as_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.require("tokens").unwrap().as_i32().unwrap(), vec![1, 2, 3, 4]);
        assert_eq!(back.require("w").unwrap().dims, vec![2, 3]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(TensorFile::from_bytes(b"NOTLAMP!....").is_err());
        assert!(TensorFile::from_bytes(b"").is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let mut file = TensorFile::new();
        file.push(Tensor::f32("w", vec![8], &[0.0; 8]).unwrap()).unwrap();
        let mut bytes = file.to_bytes();
        bytes.truncate(bytes.len() - 4);
        assert!(TensorFile::from_bytes(&bytes).is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut file = TensorFile::new();
        file.push(Tensor::f32("w", vec![1], &[0.0]).unwrap()).unwrap();
        assert!(file.push(Tensor::f32("w", vec![1], &[1.0]).unwrap()).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = Tensor::f32("x", vec![1], &[1.0]).unwrap();
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::f32("x", vec![2, 2], &[0.0; 3]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut file = TensorFile::new();
        file.push(Tensor::f32("a", vec![3], &[1.5, -2.5, 0.0]).unwrap()).unwrap();
        let path = std::env::temp_dir().join("lamp_tensorio_test.lamp");
        file.save(&path).unwrap();
        let back = TensorFile::load(&path).unwrap();
        assert_eq!(back.require("a").unwrap().as_f32().unwrap(), vec![1.5, -2.5, 0.0]);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn preserves_insertion_order() {
        let mut file = TensorFile::new();
        for name in ["z", "a", "m"] {
            file.push(Tensor::f32(name, vec![1], &[0.0]).unwrap()).unwrap();
        }
        let names: Vec<_> = file.tensors().iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, vec!["z", "a", "m"]);
        let back = TensorFile::from_bytes(&file.to_bytes()).unwrap();
        let names: Vec<_> = back.tensors().iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, vec!["z", "a", "m"]);
    }
}
