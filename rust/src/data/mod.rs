//! Synthetic corpora — the substitution for the paper's HF datasets
//! (OpenWebText, CodeParrot, ArXiv, GSM8k, WikiText-2; see DESIGN.md
//! §Substitutions).
//!
//! Each domain is a parameterized token-stream generator over the model's
//! vocabulary: a Zipfian unigram backbone blended with a seeded Markov
//! bigram chain (word-order structure), with per-domain repetition and
//! motif parameters. The permutation transform of App. C.3 is provided to
//! reproduce Fig. 6.

pub mod corpus;
pub mod dataset;
pub mod traces;
pub mod zipf;

pub use corpus::{Domain, SyntheticCorpus};
pub use dataset::{permute_tokens, Dataset};
pub use traces::{TraceKind, TraceRequest, TraceSpec};
pub use zipf::Zipf;
