//! Seeded workload-trace generators for the trials subsystem.
//!
//! A *trace* is a deterministic list of generation requests — prompt
//! tokens, generation budget, sampling seed, and a **virtual arrival
//! step** — produced entirely from a `(TraceSpec, seed)` pair. The same
//! spec and seed always yield the same trace, so a trial replayed through
//! the scheduler (`coordinator::replay`) is reproducible byte for byte.
//!
//! Beyond the lone Zipf-length mix the serving benches used, the traces
//! cover the workload shapes the serving stack is supposed to be good at:
//!
//! * [`TraceKind::ZipfMix`] — the classic natural-language length mix
//!   (many short requests, heavy tail of long generations);
//! * [`TraceKind::PrefixChat`] — multi-turn chat sessions sharing a
//!   per-session system prompt, the shape the paged-KV prefix cache
//!   (`model::kvstore`) exists for;
//! * [`TraceKind::LongContext`] — summarization-style traffic: prompts
//!   near the context window, short generations (prefill-dominated);
//! * [`TraceKind::Bursty`] — an on/off arrival process: synchronized
//!   bursts separated by idle gaps (admission-control stress);
//! * [`TraceKind::Poisson`] — Bernoulli-thinned (geometric-interarrival)
//!   arrivals at a configurable rate;
//! * [`TraceKind::Adversarial`] — worst-case prompt-length mixes:
//!   1-token prompts wanting the whole context interleaved with
//!   near-context prompts wanting one token (pool/fairness stress).
//!
//! Virtual arrival steps are *scheduler iterations*, not wall-clock time:
//! replay stays deterministic on any machine and at any thread-pool size.

use super::zipf::Zipf;
use crate::error::{Error, Result};
use crate::model::Decode;
use crate::util::Rng;

/// One request of a workload trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// Virtual arrival time in scheduler iterations (0 = enqueued before
    /// the first iteration). Non-decreasing across a generated trace.
    pub arrival_step: usize,
    /// Prompt token ids (non-empty, within the context window).
    pub prompt: Vec<u32>,
    /// Generation budget (already capped to fit the context window).
    pub new_tokens: usize,
    /// Sampling / Random-rule seed.
    pub seed: u64,
    /// Sampling strategy.
    pub decode: Decode,
}

/// The workload shapes a trace can take.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    ZipfMix,
    PrefixChat,
    LongContext,
    Bursty,
    Poisson,
    Adversarial,
}

impl TraceKind {
    /// Stable name used by trial manifests and reports.
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::ZipfMix => "zipf-mix",
            TraceKind::PrefixChat => "prefix-chat",
            TraceKind::LongContext => "long-context",
            TraceKind::Bursty => "bursty",
            TraceKind::Poisson => "poisson",
            TraceKind::Adversarial => "adversarial",
        }
    }

    /// Parse a manifest name; the error lists the valid names.
    pub fn by_name(name: &str) -> Result<Self> {
        TraceKind::all()
            .into_iter()
            .find(|k| k.name() == name)
            .ok_or_else(|| {
                let names: Vec<&str> = TraceKind::all().iter().map(|k| k.name()).collect();
                Error::config(format!(
                    "unknown trace kind {name:?} (expected one of {})",
                    names.join(", ")
                ))
            })
    }

    pub fn all() -> [TraceKind; 6] {
        [
            TraceKind::ZipfMix,
            TraceKind::PrefixChat,
            TraceKind::LongContext,
            TraceKind::Bursty,
            TraceKind::Poisson,
            TraceKind::Adversarial,
        ]
    }
}

/// Declarative description of a workload trace. Unused per-kind knobs are
/// simply ignored by the other kinds.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub kind: TraceKind,
    /// Requests in the trace.
    pub requests: usize,
    /// Model vocabulary (prompt tokens are drawn below it).
    pub vocab: usize,
    /// Model context window (prompt + generation must fit inside it).
    pub context: usize,
    /// Root seed; every token and length in the trace derives from it.
    pub seed: u64,
    /// Base generation budget per request.
    pub new_tokens: usize,
    /// `prefix-chat`: concurrent chat sessions.
    pub sessions: usize,
    /// `prefix-chat`: shared per-session system-prompt length.
    pub prefix_len: usize,
    /// `prefix-chat`: fresh user tokens appended per turn.
    pub turn_tokens: usize,
    /// `zipf-mix`/`bursty`: Zipf exponent of the length distribution.
    pub zipf_s: f64,
    /// `bursty`: requests per burst.
    pub burst: usize,
    /// `bursty`: idle scheduler iterations between bursts.
    pub gap_steps: usize,
    /// `poisson`: per-iteration arrival probability in (0, 1].
    pub rate: f64,
    /// When > 0, every third request samples top-k at this k (seeded);
    /// 0 keeps the whole trace greedy.
    pub topk: usize,
}

impl TraceSpec {
    /// A spec with workable defaults for `vocab`/`context`-sized models.
    pub fn new(kind: TraceKind, vocab: usize, context: usize) -> Self {
        TraceSpec {
            kind,
            requests: 12,
            vocab,
            context,
            seed: 1,
            new_tokens: 8,
            sessions: 3,
            prefix_len: (context / 4).max(1),
            turn_tokens: 4,
            zipf_s: 1.1,
            burst: 4,
            gap_steps: 6,
            rate: 0.35,
            topk: 0,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.requests == 0 {
            return Err(Error::config("trace: requests must be >= 1"));
        }
        if self.vocab < 2 || self.context < 8 {
            return Err(Error::config(format!(
                "trace: vocab {} / context {} too small (need vocab >= 2, context >= 8)",
                self.vocab, self.context
            )));
        }
        if self.new_tokens == 0 {
            return Err(Error::config("trace: new_tokens must be >= 1"));
        }
        if !self.zipf_s.is_finite() || self.zipf_s <= 0.0 {
            return Err(Error::config("trace: zipf_s must be > 0"));
        }
        match self.kind {
            TraceKind::PrefixChat => {
                if self.sessions == 0 || self.turn_tokens == 0 || self.prefix_len == 0 {
                    return Err(Error::config(
                        "prefix-chat: sessions, prefix-len and turn-tokens must be >= 1",
                    ));
                }
                let turns = self.requests.div_ceil(self.sessions);
                let longest = self.prefix_len + turns * self.turn_tokens;
                if longest + self.new_tokens + 1 > self.context {
                    return Err(Error::config(format!(
                        "prefix-chat: final turn needs {longest} prompt + {} generated \
                         tokens but the context is {} (shrink turns or prefix-len)",
                        self.new_tokens, self.context
                    )));
                }
            }
            TraceKind::Bursty => {
                if self.burst == 0 {
                    return Err(Error::config("bursty: burst must be >= 1"));
                }
            }
            TraceKind::Poisson => {
                // NaN fails both bounds checks below, as it should.
                let in_range = self.rate > 0.0 && self.rate <= 1.0;
                if !in_range {
                    return Err(Error::config(format!(
                        "poisson: rate {} out of (0, 1]",
                        self.rate
                    )));
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Generate the trace: deterministic in `(spec, seed)`, sorted by
    /// arrival step (ties keep generation order).
    pub fn generate(&self) -> Result<Vec<TraceRequest>> {
        self.validate()?;
        let mut out = match self.kind {
            TraceKind::ZipfMix => self.zipf_mix(),
            TraceKind::PrefixChat => self.prefix_chat(),
            TraceKind::LongContext => self.long_context(),
            TraceKind::Bursty => self.bursty(),
            TraceKind::Poisson => self.poisson(),
            TraceKind::Adversarial => self.adversarial(),
        };
        out.sort_by_key(|r| r.arrival_step);
        debug_assert!(out.iter().all(|r| {
            !r.prompt.is_empty()
                && r.new_tokens >= 1
                && r.prompt.len() + r.new_tokens < self.context
        }));
        Ok(out)
    }

    /// Per-request sampling strategy: greedy, with every third request
    /// flipped to top-k when the spec enables it.
    fn decode_for(&self, i: usize) -> Decode {
        if self.topk > 0 && i % 3 == 0 {
            Decode::TopK { k: self.topk, temperature: 1.1 }
        } else {
            Decode::Greedy
        }
    }

    /// Per-request seed stream, decorrelated across indices.
    fn seed_for(&self, i: usize) -> u64 {
        self.seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((i as u64).wrapping_mul(0x2545_F491_4F6C_DD1D))
            | 1
    }

    fn tokens(&self, rng: &mut Rng, len: usize) -> Vec<u32> {
        (0..len).map(|_| rng.below(self.vocab as u64) as u32).collect()
    }

    /// Cap a generation budget so `prompt + generated` fits the window.
    fn cap_new(&self, prompt_len: usize, want: usize) -> usize {
        let room = self.context.saturating_sub(prompt_len + 1).max(1);
        want.clamp(1, room)
    }

    /// The historical serving-bench shape: Zipf prompt and generation
    /// lengths, all arriving up front.
    fn zipf_mix(&self) -> Vec<TraceRequest> {
        let zipf = Zipf::new((self.context / 4).clamp(2, 24), self.zipf_s);
        let mut rng = Rng::new(self.seed);
        (0..self.requests)
            .map(|i| {
                let prompt_len = 2 + zipf.sample(&mut rng);
                let prompt = self.tokens(&mut rng, prompt_len);
                let want = self.new_tokens / 2 + zipf.sample(&mut rng) * 4 + 1;
                let new_tokens = self.cap_new(prompt_len, want);
                TraceRequest {
                    arrival_step: 0,
                    prompt,
                    new_tokens,
                    seed: self.seed_for(i),
                    decode: self.decode_for(i),
                }
            })
            .collect()
    }

    /// Multi-turn chat: every turn of a session re-sends the session's
    /// system prefix plus the accumulated history, so consecutive turns
    /// share a growing token prefix — the prefix-cache adoption path.
    /// All turns of a session carry the *same* seed: the prefix-share
    /// chain is keyed by `(seed, plan, token prefix)`, so intra-session
    /// reuse actually hits.
    fn prefix_chat(&self) -> Vec<TraceRequest> {
        let mut out = Vec::with_capacity(self.requests);
        let turns = self.requests.div_ceil(self.sessions);
        for s in 0..self.sessions {
            let session_seed = self.seed_for(s).wrapping_mul(0x00C6_A4A7_9352_09E7) | 1;
            let mut rng = Rng::new(session_seed);
            let mut history = self.tokens(&mut rng, self.prefix_len);
            for t in 0..turns {
                let idx = s * turns + t;
                if out.len() >= self.requests {
                    break;
                }
                history.extend(self.tokens(&mut rng, self.turn_tokens));
                out.push(TraceRequest {
                    // Interleave sessions; later turns arrive later, so a
                    // turn's prefix blocks are usually already published.
                    arrival_step: t * 3 + s,
                    prompt: history.clone(),
                    new_tokens: self.cap_new(history.len(), self.new_tokens),
                    seed: session_seed,
                    decode: self.decode_for(idx),
                });
            }
        }
        out
    }

    /// Summarization shape: prompts fill most of the window, generations
    /// are short — prefill dominates and pool pressure peaks early.
    fn long_context(&self) -> Vec<TraceRequest> {
        let mut rng = Rng::new(self.seed);
        (0..self.requests)
            .map(|i| {
                let base = self.context * 3 / 4;
                let jitter = rng.below((self.context / 8).max(1) as u64) as usize;
                let prompt_len = (base + jitter).min(self.context - self.new_tokens.min(4) - 2);
                let prompt = self.tokens(&mut rng, prompt_len);
                TraceRequest {
                    arrival_step: i,
                    prompt,
                    new_tokens: self.cap_new(prompt_len, self.new_tokens.min(4)),
                    seed: self.seed_for(i),
                    decode: self.decode_for(i),
                }
            })
            .collect()
    }

    /// On/off arrivals: bursts of `burst` Zipf-length requests separated
    /// by `gap_steps` idle iterations.
    fn bursty(&self) -> Vec<TraceRequest> {
        let zipf = Zipf::new((self.context / 4).clamp(2, 16), self.zipf_s);
        let mut rng = Rng::new(self.seed);
        (0..self.requests)
            .map(|i| {
                let burst_idx = i / self.burst;
                let prompt_len = 2 + zipf.sample(&mut rng);
                let prompt = self.tokens(&mut rng, prompt_len);
                let want = self.new_tokens + zipf.sample(&mut rng);
                let new_tokens = self.cap_new(prompt_len, want);
                TraceRequest {
                    arrival_step: burst_idx * self.gap_steps.max(1),
                    prompt,
                    new_tokens,
                    seed: self.seed_for(i),
                    decode: self.decode_for(i),
                }
            })
            .collect()
    }

    /// Bernoulli-thinned arrivals: geometric interarrival gaps at `rate`
    /// arrivals per iteration (inverse-CDF, so one f64 draw per gap).
    fn poisson(&self) -> Vec<TraceRequest> {
        let mut rng = Rng::new(self.seed);
        let mut step = 0usize;
        (0..self.requests)
            .map(|i| {
                let gap = if self.rate >= 1.0 {
                    0
                } else {
                    // u ∈ [0,1); 1-u ∈ (0,1] avoids ln(0).
                    let u = rng.f64();
                    ((1.0 - u).ln() / (1.0 - self.rate).ln()).floor() as usize
                };
                step += gap;
                let prompt_len = 2 + rng.below((self.context / 6).max(2) as u64) as usize;
                let prompt = self.tokens(&mut rng, prompt_len);
                TraceRequest {
                    arrival_step: step,
                    prompt,
                    new_tokens: self.cap_new(prompt_len, self.new_tokens),
                    seed: self.seed_for(i),
                    decode: self.decode_for(i),
                }
            })
            .collect()
    }

    /// Fairness/pool stress: 1-token prompts wanting the whole window
    /// interleaved with near-window prompts wanting one token, plus a
    /// mid-sized shape, all arriving at once.
    fn adversarial(&self) -> Vec<TraceRequest> {
        let mut rng = Rng::new(self.seed);
        (0..self.requests)
            .map(|i| {
                let (prompt_len, want) = match i % 3 {
                    // Tiny prompt, maximal generation: monopolization bait.
                    0 => (1, self.context - 2),
                    // Near-window prompt, single token: admission spike.
                    1 => (self.context - 3, 1),
                    // Mid-sized: keeps slots churning between extremes.
                    _ => (self.context / 2, self.new_tokens),
                };
                let prompt = self.tokens(&mut rng, prompt_len);
                TraceRequest {
                    arrival_step: 0,
                    prompt,
                    new_tokens: self.cap_new(prompt_len, want),
                    seed: self.seed_for(i),
                    decode: self.decode_for(i),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(kind: TraceKind) -> TraceSpec {
        TraceSpec::new(kind, 256, 128)
    }

    #[test]
    fn every_kind_generates_valid_requests() {
        for kind in TraceKind::all() {
            let s = spec(kind);
            let trace = s.generate().unwrap();
            assert_eq!(trace.len(), s.requests, "{}", kind.name());
            let mut last_arrival = 0;
            for r in &trace {
                assert!(!r.prompt.is_empty(), "{}", kind.name());
                assert!(r.new_tokens >= 1);
                assert!(
                    r.prompt.len() + r.new_tokens < s.context,
                    "{}: {} prompt + {} new >= context {}",
                    kind.name(),
                    r.prompt.len(),
                    r.new_tokens,
                    s.context
                );
                assert!(r.prompt.iter().all(|&t| (t as usize) < s.vocab));
                assert!(r.arrival_step >= last_arrival, "sorted by arrival");
                last_arrival = r.arrival_step;
            }
        }
    }

    #[test]
    fn same_seed_same_trace_different_seed_differs() {
        for kind in TraceKind::all() {
            let a = spec(kind).generate().unwrap();
            let b = spec(kind).generate().unwrap();
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.prompt, y.prompt, "{}", kind.name());
                assert_eq!(x.new_tokens, y.new_tokens);
                assert_eq!(x.seed, y.seed);
                assert_eq!(x.arrival_step, y.arrival_step);
            }
            let mut other = spec(kind);
            other.seed = 999;
            let c = other.generate().unwrap();
            assert!(
                a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt),
                "{}: reseeding must change the trace",
                kind.name()
            );
        }
    }

    #[test]
    fn prefix_chat_turns_share_prefixes() {
        let s = spec(TraceKind::PrefixChat);
        let trace = s.generate().unwrap();
        // Group by seed (= session); within a session, every prompt is a
        // strict prefix of the next turn's prompt.
        let mut by_seed: Vec<(u64, Vec<&TraceRequest>)> = Vec::new();
        for r in &trace {
            match by_seed.iter_mut().find(|(seed, _)| *seed == r.seed) {
                Some((_, v)) => v.push(r),
                None => by_seed.push((r.seed, vec![r])),
            }
        }
        assert_eq!(by_seed.len(), s.sessions);
        for (_, turns) in &by_seed {
            for w in turns.windows(2) {
                let (a, b) = (&w[0].prompt, &w[1].prompt);
                assert!(a.len() < b.len());
                assert_eq!(&b[..a.len()], &a[..], "turn prompts must nest");
            }
        }
    }

    #[test]
    fn bursty_arrivals_cluster_and_poisson_spreads() {
        let b = spec(TraceKind::Bursty).generate().unwrap();
        let distinct: std::collections::BTreeSet<usize> =
            b.iter().map(|r| r.arrival_step).collect();
        assert_eq!(distinct.len(), 12usize.div_ceil(4), "one step per burst");
        let p = spec(TraceKind::Poisson).generate().unwrap();
        assert!(p.last().unwrap().arrival_step > 0, "arrivals must spread out");
    }

    #[test]
    fn adversarial_mixes_extremes() {
        let s = spec(TraceKind::Adversarial);
        let trace = s.generate().unwrap();
        assert!(trace.iter().any(|r| r.prompt.len() == 1));
        assert!(trace.iter().any(|r| r.prompt.len() >= s.context - 3));
        assert!(trace.iter().any(|r| r.new_tokens == 1));
        assert!(trace.iter().any(|r| r.new_tokens >= s.context / 2));
    }

    #[test]
    fn topk_spec_mixes_decodes() {
        let mut s = spec(TraceKind::ZipfMix);
        s.topk = 4;
        let trace = s.generate().unwrap();
        assert!(trace.iter().any(|r| matches!(r.decode, Decode::TopK { .. })));
        assert!(trace.iter().any(|r| matches!(r.decode, Decode::Greedy)));
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut s = spec(TraceKind::Poisson);
        s.rate = 0.0;
        assert!(s.generate().is_err());
        let mut s = spec(TraceKind::PrefixChat);
        s.prefix_len = 120; // prefix + turns won't fit the 128 window
        assert!(s.generate().is_err());
        let mut s = spec(TraceKind::ZipfMix);
        s.requests = 0;
        assert!(s.generate().is_err());
        let mut s = spec(TraceKind::Bursty);
        s.burst = 0;
        assert!(s.generate().is_err());
    }

    #[test]
    fn kind_names_round_trip() {
        for kind in TraceKind::all() {
            assert_eq!(TraceKind::by_name(kind.name()).unwrap(), kind);
        }
        assert!(TraceKind::by_name("bogus").is_err());
    }
}
