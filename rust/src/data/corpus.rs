//! Per-domain synthetic corpus generators.
//!
//! A corpus is a Zipf-unigram + Markov-bigram mixture:
//!
//! ```text
//!   t_{i+1} ~ (1 − λ)·Zipf(s)  +  λ·Markov(t_i)
//! ```
//!
//! where the Markov table is itself seeded per domain (deterministic,
//! reproducible in both the Rust harness and the Python training script).
//! Per-domain parameters approximate the statistics relevant to LAMP:
//! unigram concentration (softmax sharpness through training), bigram
//! coherence (word order; destroyed by the App. C.3 permutation), and
//! repetition (code's long-range copy structure).

use super::zipf::Zipf;
use crate::util::Rng;

/// The evaluation domains standing in for the paper's datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Domain {
    /// OpenWebText analogue: natural-language-like Zipf(1.05), moderate
    /// bigram coherence.
    Web,
    /// CodeParrot analogue: highly repetitive, peaked unigram, strong local
    /// structure, explicit repetition loops.
    Code,
    /// ArXiv analogue: flatter unigram (rich technical vocabulary), long
    /// coherent motifs.
    Arxiv,
    /// GSM8k analogue: short arithmetic-flavoured patterns over a narrow
    /// token subset.
    Math,
    /// WikiText-2 analogue: web-like with slightly flatter unigram.
    Wiki,
}

impl Domain {
    pub fn name(self) -> &'static str {
        match self {
            Domain::Web => "web",
            Domain::Code => "code",
            Domain::Arxiv => "arxiv",
            Domain::Math => "math",
            Domain::Wiki => "wiki",
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "web" => Some(Domain::Web),
            "code" => Some(Domain::Code),
            "arxiv" => Some(Domain::Arxiv),
            "math" => Some(Domain::Math),
            "wiki" => Some(Domain::Wiki),
            _ => None,
        }
    }

    /// (zipf_s, markov_weight λ, repeat_prob, motif_len)
    fn params(self) -> (f64, f64, f64, usize) {
        match self {
            Domain::Web => (1.05, 0.55, 0.02, 4),
            Domain::Code => (1.35, 0.70, 0.20, 6),
            Domain::Arxiv => (0.95, 0.60, 0.05, 8),
            Domain::Math => (1.25, 0.65, 0.10, 3),
            Domain::Wiki => (1.00, 0.55, 0.03, 4),
        }
    }

    /// All domains.
    pub fn all() -> [Domain; 5] {
        [Domain::Web, Domain::Code, Domain::Arxiv, Domain::Math, Domain::Wiki]
    }
}

/// A deterministic synthetic token-stream generator for one domain.
pub struct SyntheticCorpus {
    vocab: usize,
    zipf: Zipf,
    /// Markov successor table: for each token, `branch` candidate
    /// successors with geometric weights.
    successors: Vec<Vec<usize>>,
    lambda: f64,
    repeat_prob: f64,
    motif_len: usize,
    rng: Rng,
    /// Recent history for repetition.
    history: Vec<usize>,
}

impl SyntheticCorpus {
    /// Construct a generator for `domain` over `vocab` tokens.
    ///
    /// The Markov table depends only on (domain, vocab, table_seed), so the
    /// Python training corpus and the Rust evaluation corpus share structure
    /// when given the same seed (they intentionally use different *stream*
    /// seeds to get disjoint train/eval data).
    pub fn new(domain: Domain, vocab: usize, table_seed: u64, stream_seed: u64) -> Self {
        assert!(vocab >= 8, "vocab too small");
        let (s, lambda, repeat_prob, motif_len) = domain.params();
        let zipf = Zipf::new(vocab, s);
        let branch = 4;
        let mut table_rng = Rng::new(table_seed ^ (domain as u64).wrapping_mul(0x9E3779B9));
        let successors = (0..vocab)
            .map(|_| {
                (0..branch)
                    .map(|_| zipf.sample(&mut table_rng))
                    .collect::<Vec<usize>>()
            })
            .collect();
        SyntheticCorpus {
            vocab,
            zipf,
            successors,
            lambda,
            repeat_prob,
            motif_len,
            rng: Rng::new(stream_seed),
            history: Vec::new(),
        }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Next token in the stream.
    pub fn next_token(&mut self) -> u32 {
        // Repetition: replay a recent motif (code-like copy structure).
        if self.history.len() > 2 * self.motif_len && self.rng.f64() < self.repeat_prob {
            let start = self.history.len() - self.motif_len;
            let tok = self.history[start + self.history.len() % self.motif_len];
            self.history.push(tok);
            return tok as u32;
        }
        let tok = if let Some(&prev) = self.history.last() {
            if self.rng.f64() < self.lambda {
                // Markov step: geometric choice among the successor list.
                let succ = &self.successors[prev];
                let mut idx = 0;
                while idx + 1 < succ.len() && self.rng.f64() < 0.4 {
                    idx += 1;
                }
                succ[idx]
            } else {
                self.zipf.sample(&mut self.rng)
            }
        } else {
            self.zipf.sample(&mut self.rng)
        };
        self.history.push(tok);
        if self.history.len() > 64 {
            self.history.drain(0..32);
        }
        tok as u32
    }

    /// Generate a sequence of `len` tokens.
    pub fn sequence(&mut self, len: usize) -> Vec<u32> {
        (0..len).map(|_| self.next_token()).collect()
    }

    /// Generate `count` sequences of `len` tokens each.
    pub fn sequences(&mut self, count: usize, len: usize) -> Vec<Vec<u32>> {
        (0..count).map(|_| self.sequence(len)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        for d in Domain::all() {
            let mut c = SyntheticCorpus::new(d, 128, 7, 42);
            for _ in 0..2000 {
                assert!((c.next_token() as usize) < 128);
            }
        }
    }

    #[test]
    fn deterministic_given_seeds() {
        let mut a = SyntheticCorpus::new(Domain::Web, 256, 7, 42);
        let mut b = SyntheticCorpus::new(Domain::Web, 256, 7, 42);
        assert_eq!(a.sequence(512), b.sequence(512));
    }

    #[test]
    fn different_stream_seeds_differ() {
        let mut a = SyntheticCorpus::new(Domain::Web, 256, 7, 1);
        let mut b = SyntheticCorpus::new(Domain::Web, 256, 7, 2);
        assert_ne!(a.sequence(256), b.sequence(256));
    }

    #[test]
    fn code_more_repetitive_than_arxiv() {
        // Measure bigram repetition rate (same bigram seen before).
        let rate = |d: Domain| {
            let mut c = SyntheticCorpus::new(d, 256, 7, 9);
            let seq = c.sequence(4000);
            let mut seen = std::collections::HashSet::new();
            let mut repeats = 0usize;
            for w in seq.windows(2) {
                if !seen.insert((w[0], w[1])) {
                    repeats += 1;
                }
            }
            repeats as f64 / (seq.len() - 1) as f64
        };
        let code = rate(Domain::Code);
        let arxiv = rate(Domain::Arxiv);
        assert!(code > arxiv, "code={code} arxiv={arxiv}");
    }

    #[test]
    fn unigram_zipf_like() {
        let mut c = SyntheticCorpus::new(Domain::Web, 128, 7, 11);
        let seq = c.sequence(50_000);
        let mut counts = vec![0usize; 128];
        for &t in &seq {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // Head token much more frequent than median token.
        assert!(counts[0] > 5 * counts[64].max(1));
    }

    #[test]
    fn domain_names_roundtrip() {
        for d in Domain::all() {
            assert_eq!(Domain::by_name(d.name()), Some(d));
        }
        assert_eq!(Domain::by_name("bogus"), None);
    }
}
