//! Evaluation datasets: fixed panels of token sequences, with the
//! token-permutation transform of App. C.3.

use super::corpus::{Domain, SyntheticCorpus};
use crate::util::Rng;

/// A fixed panel of evaluation sequences.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    pub domain: Domain,
    pub sequences: Vec<Vec<u32>>,
}

impl Dataset {
    /// Generate `count` sequences of `len` tokens from `domain`.
    ///
    /// `table_seed` must match the one used at training time (7 — see
    /// `python/compile/train.py`) so the evaluation stream has the same
    /// Markov structure the model was trained on; `stream_seed` selects a
    /// held-out stream.
    pub fn generate(
        domain: Domain,
        vocab: usize,
        count: usize,
        len: usize,
        table_seed: u64,
        stream_seed: u64,
    ) -> Self {
        let mut corpus = SyntheticCorpus::new(domain, vocab, table_seed, stream_seed);
        Dataset { domain, sequences: corpus.sequences(count, len) }
    }

    /// Number of sequences.
    pub fn len(&self) -> usize {
        self.sequences.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sequences.is_empty()
    }

    /// Total token count.
    pub fn tokens(&self) -> usize {
        self.sequences.iter().map(|s| s.len()).sum()
    }

    /// App. C.3: permute the tokens within each sequence at random,
    /// destroying word order while preserving the unigram distribution.
    pub fn permuted(&self, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let sequences = self
            .sequences
            .iter()
            .map(|s| {
                let mut p = s.clone();
                permute_tokens(&mut p, &mut rng);
                p
            })
            .collect();
        Dataset { domain: self.domain, sequences }
    }
}

/// In-place random permutation of one token sequence.
pub fn permute_tokens(seq: &mut [u32], rng: &mut Rng) {
    rng.shuffle(seq);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_shapes() {
        let d = Dataset::generate(Domain::Web, 128, 4, 32, 7, 1);
        assert_eq!(d.len(), 4);
        assert_eq!(d.tokens(), 128);
        assert!(d.sequences.iter().all(|s| s.len() == 32));
    }

    #[test]
    fn permutation_preserves_multiset() {
        let d = Dataset::generate(Domain::Code, 64, 2, 64, 7, 2);
        let p = d.permuted(99);
        for (orig, perm) in d.sequences.iter().zip(&p.sequences) {
            let mut a = orig.clone();
            let mut b = perm.clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
        // And actually changes order (overwhelmingly likely at len 64).
        assert_ne!(d.sequences[0], p.sequences[0]);
    }

    #[test]
    fn deterministic() {
        let a = Dataset::generate(Domain::Math, 64, 2, 16, 7, 3);
        let b = Dataset::generate(Domain::Math, 64, 2, 16, 7, 3);
        assert_eq!(a, b);
        assert_eq!(a.permuted(5), b.permuted(5));
    }
}
