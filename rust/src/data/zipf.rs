//! Zipfian sampler over a finite vocabulary: P(rank k) ∝ 1/k^s.
//!
//! Natural-language unigram distributions are approximately Zipf(s≈1);
//! code is more repetitive (larger s); shuffled scientific text flatter
//! (smaller s). Uses an alias-free inverse-CDF table (vocab is small).

use crate::util::Rng;

/// Precomputed Zipf distribution over `n` ranks.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build with exponent `s > 0` over `n ≥ 1` outcomes.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1 && s > 0.0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Sample a rank in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // Binary search for the first cdf entry >= u.
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability of rank k; 0.0 outside `[0, n)` (the support), so
    /// callers can probe any rank without panicking on the cdf bounds.
    pub fn pmf(&self, k: usize) -> f64 {
        if k >= self.cdf.len() {
            0.0
        } else if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_normalized() {
        let z = Zipf::new(100, 1.0);
        assert!((z.cdf.last().unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rank_frequencies_decay() {
        let z = Zipf::new(50, 1.2);
        let mut rng = Rng::new(1);
        let mut counts = vec![0usize; 50];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[4] > counts[20]);
        // Empirical rank-1 frequency ≈ pmf(0).
        let p0 = counts[0] as f64 / 200_000.0;
        assert!((p0 - z.pmf(0)).abs() < 0.01, "p0={p0} pmf={}", z.pmf(0));
    }

    #[test]
    fn larger_s_more_peaked() {
        let flat = Zipf::new(100, 0.5);
        let peaked = Zipf::new(100, 2.0);
        assert!(peaked.pmf(0) > flat.pmf(0));
    }

    #[test]
    fn pmf_out_of_range_is_zero() {
        let z = Zipf::new(10, 1.0);
        assert_eq!(z.pmf(10), 0.0);
        assert_eq!(z.pmf(usize::MAX), 0.0);
        assert!(z.pmf(9) > 0.0);
    }

    #[test]
    fn pmf_sums_to_one_and_matches_sample_frequencies() {
        // Property (fixed seed): Σ pmf(k) ≈ 1 over the support, and the
        // empirical frequency of every rank tracks its pmf.
        let n = 40;
        let z = Zipf::new(n, 1.3);
        let total: f64 = (0..n).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-12, "pmf sums to {total}");
        // Including out-of-range ranks changes nothing.
        let padded: f64 = (0..2 * n).map(|k| z.pmf(k)).sum();
        assert!((padded - 1.0).abs() < 1e-12);

        let draws = 400_000usize;
        let mut rng = Rng::new(1234);
        let mut counts = vec![0usize; n];
        for _ in 0..draws {
            counts[z.sample(&mut rng)] += 1;
        }
        for k in 0..n {
            let freq = counts[k] as f64 / draws as f64;
            let p = z.pmf(k);
            // Loose Bernoulli bound: 4 sigma plus an absolute floor for
            // the tiny tail probabilities.
            let tol = 4.0 * (p * (1.0 - p) / draws as f64).sqrt() + 5e-4;
            assert!(
                (freq - p).abs() <= tol,
                "rank {k}: freq {freq:.5} vs pmf {p:.5} (tol {tol:.5})"
            );
        }
    }

    #[test]
    fn single_outcome() {
        let z = Zipf::new(1, 1.0);
        let mut rng = Rng::new(2);
        assert_eq!(z.sample(&mut rng), 0);
        assert!((z.pmf(0) - 1.0).abs() < 1e-12);
    }
}
