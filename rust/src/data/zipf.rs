//! Zipfian sampler over a finite vocabulary: P(rank k) ∝ 1/k^s.
//!
//! Natural-language unigram distributions are approximately Zipf(s≈1);
//! code is more repetitive (larger s); shuffled scientific text flatter
//! (smaller s). Uses an alias-free inverse-CDF table (vocab is small).

use crate::util::Rng;

/// Precomputed Zipf distribution over `n` ranks.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build with exponent `s > 0` over `n ≥ 1` outcomes.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1 && s > 0.0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Sample a rank in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // Binary search for the first cdf entry >= u.
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability of rank k.
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_normalized() {
        let z = Zipf::new(100, 1.0);
        assert!((z.cdf.last().unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rank_frequencies_decay() {
        let z = Zipf::new(50, 1.2);
        let mut rng = Rng::new(1);
        let mut counts = vec![0usize; 50];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[4] > counts[20]);
        // Empirical rank-1 frequency ≈ pmf(0).
        let p0 = counts[0] as f64 / 200_000.0;
        assert!((p0 - z.pmf(0)).abs() < 0.01, "p0={p0} pmf={}", z.pmf(0));
    }

    #[test]
    fn larger_s_more_peaked() {
        let flat = Zipf::new(100, 0.5);
        let peaked = Zipf::new(100, 2.0);
        assert!(peaked.pmf(0) > flat.pmf(0));
    }

    #[test]
    fn single_outcome() {
        let z = Zipf::new(1, 1.0);
        let mut rng = Rng::new(2);
        assert_eq!(z.sample(&mut rng), 0);
        assert!((z.pmf(0) - 1.0).abs() < 1e-12);
    }
}
