//! Offline stub of the `xla-rs` PJRT binding surface used by the LAMP
//! runtime (`rust/src/runtime/executor.rs`) and the serving integration
//! tests.
//!
//! The build environment has no network access and no prebuilt
//! `xla_extension`, so this crate keeps the whole workspace compiling
//! without it: every entry point that would touch PJRT returns
//! [`Error::Unavailable`] from `PjRtClient::cpu()` onwards, and callers
//! surface that as a `lamp::Error::Runtime`. All artifact-gated tests and
//! examples already skip gracefully when the compiled artifacts are
//! absent, so the stub never panics a green path.
//!
//! To enable the real compiled-artifact engine, replace the `xla` path
//! dependency in the workspace `Cargo.toml` with a real `xla-rs`
//! checkout; the API below deliberately mirrors its names and shapes
//! (`PjRtClient`, `PjRtLoadedExecutable`, `PjRtBuffer`, `Literal`,
//! `HloModuleProto`, `XlaComputation`).

use std::fmt;

/// Stub error: every PJRT operation reports the backend as unavailable.
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub backend cannot execute anything.
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "xla backend unavailable (offline stub): {what}")
            }
        }
    }
}

impl std::error::Error for Error {}

/// Stub result alias, mirroring `xla::Result`.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error::Unavailable(what.to_string())
}

/// A parsed HLO module. The stub never parses anything.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path:?})")))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A host literal (dense array + shape).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: Copy>(_v: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// The PJRT client. `cpu()` is the single construction point, so failing
/// here gates every downstream runtime path.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable(
            "PjRtClient::cpu — built against the bundled stub; \
             swap in a real xla-rs checkout to enable PJRT",
        ))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable bound to a client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
