//! Cross-engine parity: the PJRT artifact engine vs the native Rust engine
//! on the real trained artifacts. This is the capstone integration test of
//! the three-layer architecture: L1 (pallas PS(μ) kernel) + L2 (jax model)
//! lowered to HLO must reproduce the bit-exact native PS(μ) semantics.
//!
//! Skipped gracefully when `make artifacts` has not run.

use lamp::coordinator::{Engine, NativeEngine, PjrtEngine, PrecisionPolicy, Rule};
use lamp::data::{Dataset, Domain};
use lamp::metrics::mean_kl_from_logits;
use lamp::runtime::ArtifactStore;

fn store() -> Option<ArtifactStore> {
    let store = ArtifactStore::open(ArtifactStore::default_dir()).ok()?;
    if store.available_models().contains(&"nano".to_string()) {
        Some(store)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn panel(store: &ArtifactStore, name: &str) -> (PjrtEngine, NativeEngine, Vec<Vec<u32>>) {
    let pjrt = PjrtEngine::load(store, name).expect("load pjrt engine");
    let native = NativeEngine::load(store, name).expect("load native engine");
    let cfg = pjrt.config().clone();
    let data = Dataset::generate(Domain::Web, cfg.vocab, cfg.batch, cfg.seq, 7, 123);
    (pjrt, native, data.sequences)
}

/// Max |a-b| relative to the logit scale across the batch.
fn max_diff(a: &[lamp::linalg::Matrix], b: &[lamp::linalg::Matrix]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| x.max_abs_diff(y).unwrap())
        .fold(0.0, f32::max)
}

#[test]
fn parity_reference_mode() {
    let Some(store) = store() else { return };
    let (pjrt, native, tokens) = panel(&store, "nano");
    let policy = PrecisionPolicy::reference();
    let a = pjrt.infer(&tokens, &policy, 0).unwrap();
    let b = native.infer(&tokens, &policy, 0).unwrap();
    assert_eq!(a.stats.recomputed, 0);
    assert_eq!(b.stats.recomputed, 0);
    assert_eq!(a.stats.causal_total, b.stats.causal_total);
    let d = max_diff(&a.logits, &b.logits);
    assert!(d < 5e-3, "reference logits diverge: {d}");
}

#[test]
fn parity_uniform_low_precision() {
    let Some(store) = store() else { return };
    let (pjrt, native, tokens) = panel(&store, "nano");
    for mu in [2u32, 4, 7, 10] {
        let policy = PrecisionPolicy::uniform(mu);
        let a = pjrt.infer(&tokens, &policy, 0).unwrap();
        let b = native.infer(&tokens, &policy, 0).unwrap();
        let d = max_diff(&a.logits, &b.logits);
        // PS(μ) scores are bit-identical (sequential FMA + identical RNE);
        // remaining drift comes from FP32 matmul reduction order.
        assert!(d < 5e-2, "mu={mu}: logits diverge {d}");
        let kl = a
            .logits
            .iter()
            .zip(&b.logits)
            .map(|(x, y)| mean_kl_from_logits(x, y))
            .sum::<f64>();
        assert!(kl < 1e-4, "mu={mu}: engines disagree, kl={kl}");
    }
}

#[test]
fn parity_strict_lamp_counts() {
    let Some(store) = store() else { return };
    let (pjrt, native, tokens) = panel(&store, "nano");
    for (mu, tau) in [(4u32, 0.1f32), (4, 0.02), (7, 0.1), (2, 0.3)] {
        let policy = PrecisionPolicy::lamp(mu, tau, Rule::Strict);
        let a = pjrt.infer(&tokens, &policy, 0).unwrap();
        let b = native.infer(&tokens, &policy, 0).unwrap();
        // Counts must agree essentially exactly: selection happens on the
        // bit-identical PS scores. Allow a sliver for downstream-layer
        // drift moving borderline sensitivities across the threshold.
        let (ca, cb) = (a.stats.recomputed as f64, b.stats.recomputed as f64);
        assert!(
            (ca - cb).abs() <= 0.01 * ca.max(cb).max(100.0),
            "mu={mu} tau={tau}: counts diverge pjrt={ca} native={cb}"
        );
        assert!(max_diff(&a.logits, &b.logits) < 5e-2);
    }
}

#[test]
fn parity_relaxed_and_ln() {
    let Some(store) = store() else { return };
    let (pjrt, native, tokens) = panel(&store, "nano");
    for rule in [Rule::Relaxed, Rule::RelaxedLengthNorm] {
        let policy = PrecisionPolicy::lamp(4, 0.1, rule);
        let a = pjrt.infer(&tokens, &policy, 0).unwrap();
        let b = native.infer(&tokens, &policy, 0).unwrap();
        let (ca, cb) = (a.stats.recomputed as f64, b.stats.recomputed as f64);
        assert!(
            (ca - cb).abs() <= 0.01 * ca.max(cb).max(100.0),
            "{rule:?}: counts diverge pjrt={ca} native={cb}"
        );
    }
}

#[test]
fn random_rule_count_parity_positions_differ() {
    let Some(store) = store() else { return };
    let (pjrt, native, tokens) = panel(&store, "nano");
    let strict = PrecisionPolicy::lamp(3, 0.05, Rule::Strict);
    let random = PrecisionPolicy::lamp(3, 0.05, Rule::Random);
    let s = pjrt.infer(&tokens, &strict, 0).unwrap();
    let r = pjrt.infer(&tokens, &random, 0).unwrap();
    // The Random budget equals strict's count per attention call on the
    // same scores; across layers the random recomputations perturb
    // downstream activations, so totals drift by a handful of products.
    let (cs, cr) = (s.stats.recomputed as f64, r.stats.recomputed as f64);
    assert!(
        (cs - cr).abs() <= 0.02 * cs.max(cr).max(50.0),
        "strict={cs} random={cr}"
    );
    // Native random uses a different stream — counts still match budget.
    let rn = native.infer(&tokens, &random, 0).unwrap();
    let (a, b) = (r.stats.recomputed as f64, rn.stats.recomputed as f64);
    assert!((a - b).abs() <= 0.05 * a.max(b).max(50.0), "pjrt={a} native={b}");
}

#[test]
fn pjrt_rejects_tile_rules() {
    // The compiled artifact implements mode codes 0-3 only; tile rules
    // (PR 8) are native-engine features and must be rejected at submit.
    let Some(store) = store() else { return };
    let (pjrt, native, _) = panel(&store, "nano");
    for rule in [Rule::Tile { width: 8 }, Rule::TileRandom { width: 8 }] {
        let policy = PrecisionPolicy::lamp(4, 0.05, rule);
        let e = pjrt.validate_policy(&policy).unwrap_err().to_string();
        assert!(e.contains("tile"), "{e}");
        native.validate_policy(&policy).unwrap();
    }
}

#[test]
fn pjrt_lamp_improves_over_uniform_on_trained_model() {
    // The headline behaviour, measured end-to-end through the artifact.
    let Some(store) = store() else { return };
    let (pjrt, _, tokens) = panel(&store, "nano");
    let reference = pjrt.infer(&tokens, &PrecisionPolicy::reference(), 0).unwrap();
    let uniform = pjrt.infer(&tokens, &PrecisionPolicy::uniform(3), 0).unwrap();
    let lamp = pjrt
        .infer(&tokens, &PrecisionPolicy::lamp(3, 0.05, Rule::Strict), 0)
        .unwrap();
    let kl_uni: f64 = reference
        .logits
        .iter()
        .zip(&uniform.logits)
        .map(|(r, t)| mean_kl_from_logits(r, t))
        .sum();
    let kl_lamp: f64 = reference
        .logits
        .iter()
        .zip(&lamp.logits)
        .map(|(r, t)| mean_kl_from_logits(r, t))
        .sum();
    assert!(lamp.stats.recomputed > 0);
    assert!(
        kl_lamp < kl_uni,
        "LAMP must improve KL through the artifact path: lamp={kl_lamp} uni={kl_uni}"
    );
}
