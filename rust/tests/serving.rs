//! End-to-end serving integration: batching server over both engines with
//! the real trained artifacts.

use lamp::coordinator::{
    Engine, InferenceRequest, NativeEngine, PjrtEngine, PrecisionPolicy, Server,
};
use lamp::data::{Dataset, Domain};
use lamp::runtime::ArtifactStore;
use std::time::Duration;

fn store() -> Option<ArtifactStore> {
    let store = ArtifactStore::open(ArtifactStore::default_dir()).ok()?;
    if store.available_models().contains(&"nano".to_string()) {
        Some(store)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn run_workload(engine: Box<dyn Engine>, n: usize) -> lamp::coordinator::ServerStats {
    let cfg = engine.config().clone();
    let policy = PrecisionPolicy::tier("balanced").unwrap();
    let dataset = Dataset::generate(Domain::Web, cfg.vocab, n, cfg.seq, 7, 5);
    let mut server = Server::new(engine, Duration::from_millis(2));
    let mut served = 0;
    for (i, seq) in dataset.sequences.into_iter().enumerate() {
        // Vary lengths to exercise padding.
        let len = 4 + (i * 7) % (cfg.seq - 4);
        let seq = seq[..len].to_vec();
        server.submit(InferenceRequest::new(i as u64, seq, policy)).unwrap();
        served += server.step(false).unwrap().len();
    }
    served += server.drain().unwrap().len();
    assert_eq!(served, n);
    server.stats()
}

#[test]
fn serve_pjrt_nano_workload() {
    let Some(store) = store() else { return };
    let engine = PjrtEngine::load(&store, "nano").unwrap();
    let stats = run_workload(Box::new(engine), 10);
    assert_eq!(stats.requests, 10);
    assert!(stats.batches >= 5);
    assert!(stats.throughput_tok_s > 0.0);
    assert!(stats.recomputed > 0, "balanced tier must recompute on trained nano");
}

#[test]
fn serve_native_nano_workload() {
    let Some(store) = store() else { return };
    let engine = NativeEngine::load(&store, "nano").unwrap();
    let stats = run_workload(Box::new(engine), 10);
    assert_eq!(stats.requests, 10);
    assert!(stats.latency_p95_s >= stats.latency_mean_s * 0.5);
}

#[test]
fn per_request_logits_independent_of_batchmates() {
    // Serve the same request next to different batch-mates on the PJRT
    // engine; causal padding isolation must hold through the artifact.
    let Some(store) = store() else { return };
    let engine1 = PjrtEngine::load(&store, "nano").unwrap();
    let policy = PrecisionPolicy::reference();
    let probe = vec![5u32, 17, 40, 11];

    let mut s1 = Server::new(Box::new(engine1), Duration::from_millis(1));
    s1.submit(InferenceRequest::new(1, probe.clone(), policy)).unwrap();
    s1.submit(InferenceRequest::new(2, vec![100, 101, 102], policy)).unwrap();
    let mut r1 = s1.drain().unwrap();
    r1.sort_by_key(|r| r.id);

    let engine2 = PjrtEngine::load(&store, "nano").unwrap();
    let mut s2 = Server::new(Box::new(engine2), Duration::from_millis(1));
    s2.submit(InferenceRequest::new(1, probe, policy)).unwrap();
    s2.submit(InferenceRequest::new(2, vec![7, 8, 9, 10, 11], policy)).unwrap();
    let mut r2 = s2.drain().unwrap();
    r2.sort_by_key(|r| r.id);

    assert_eq!(r1[0].logits, r2[0].logits, "batch-mates leaked into logits");
}

#[test]
fn kernel_artifacts_execute() {
    // The standalone L1 kernel artifacts load and run through PJRT.
    let Some(store) = store() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    for kernel in ["ps_matmul", "lamp_attention"] {
        let path = store.kernel_hlo(kernel);
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let comp = xla::XlaComputation::from_proto(&proto);
        let _exe = client.compile(&comp).expect(kernel);
    }
}

#[test]
fn ps_matmul_kernel_matches_native_softfloat() {
    // Execute kernel_ps_matmul.hlo.txt and compare against the rust
    // softfloat matmul bit-for-bit.
    let Some(store) = store() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file(
        store.kernel_hlo("ps_matmul").to_str().unwrap(),
    )
    .unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();

    let mut rng = lamp::util::Rng::new(9);
    let a = lamp::linalg::Matrix::randn(64, 64, 1.0, &mut rng);
    let b = lamp::linalg::Matrix::randn(64, 64, 1.0, &mut rng);
    for mu in [2i32, 4, 7, 23] {
        let la = xla::Literal::vec1(a.data()).reshape(&[64, 64]).unwrap();
        let lb = xla::Literal::vec1(b.data()).reshape(&[64, 64]).unwrap();
        let lmu = xla::Literal::scalar(mu);
        let out = exe.execute::<xla::Literal>(&[la, lb, lmu]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        let got = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();
        let want = lamp::linalg::matmul_ps(&a, &b, mu as u32).unwrap();
        let n_diff = got
            .iter()
            .zip(want.data())
            .filter(|(x, y)| x.to_bits() != y.to_bits())
            .count();
        assert_eq!(n_diff, 0, "mu={mu}: {n_diff}/4096 entries differ");
    }
}

#[test]
fn greedy_generation_on_trained_model_flips_under_low_precision() {
    // On the *trained* nano model, PS(1) KQ accumulation should change at
    // least one greedy continuation across a handful of prompts — and the
    // LAMP-repaired path should restore the reference continuation more
    // often than the uniform low-precision path breaks it.
    use lamp::model::{generate, Decode};
    let Some(store) = store() else { return };
    let weights = store.weights("nano").unwrap();
    let cfg = weights.config.clone();
    let mut flips_uniform = 0usize;
    let mut flips_lamp = 0usize;
    let n_prompts = 6;
    for p in 0..n_prompts {
        let prompt =
            Dataset::generate(Domain::Web, cfg.vocab, 1, 8, 7, 100 + p as u64).sequences.remove(0);
        let reference = generate(
            &weights,
            &prompt,
            8,
            lamp::model::AttentionPrecision::reference(),
            Decode::Greedy,
            0,
        )
        .unwrap()
        .0;
        let uniform = generate(
            &weights,
            &prompt,
            8,
            lamp::model::AttentionPrecision::uniform(1),
            Decode::Greedy,
            0,
        )
        .unwrap()
        .0;
        let lamp_prec = lamp::model::AttentionPrecision::lamp(
            1,
            0.02,
            lamp::lamp::softmax::SoftmaxRule::Strict,
        );
        let repaired = generate(&weights, &prompt, 8, lamp_prec, Decode::Greedy, 0).unwrap().0;
        if uniform != reference {
            flips_uniform += 1;
        }
        if repaired != reference {
            flips_lamp += 1;
        }
    }
    assert!(
        flips_uniform > 0,
        "PS(1) never changed a greedy continuation on the trained model"
    );
    assert!(
        flips_lamp <= flips_uniform,
        "LAMP repaired fewer continuations than uniform: lamp={flips_lamp} uniform={flips_uniform}"
    );
}
