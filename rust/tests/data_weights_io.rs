//! Cross-language I/O integration: the rust loader against the actual
//! artifacts written by the Python compile path.

use lamp::model::{ModelConfig, Weights};
use lamp::runtime::ArtifactStore;

fn store() -> Option<ArtifactStore> {
    let store = ArtifactStore::open(ArtifactStore::default_dir()).ok()?;
    if store.available_models().is_empty() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(store)
}

#[test]
fn trained_weights_load_for_all_models() {
    let Some(store) = store() else { return };
    for name in store.available_models() {
        let cfg = store.model_config(&name).unwrap();
        let w = store.weights(&name).unwrap();
        assert_eq!(w.config, cfg);
        assert_eq!(w.blocks.len(), cfg.layers);
        // Trained weights must not be all-zero or NaN.
        let wte = w.wte.to_f32_vec();
        assert!(wte.iter().all(|x| x.is_finite()));
        let norm: f64 = wte.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(norm > 0.1, "{name}: wte looks untrained/zero (norm={norm})");
    }
}

#[test]
fn meta_matches_registry() {
    // The artifact metadata must agree with the rust-side registry configs
    // (they are maintained in parallel — this test pins them together).
    let Some(store) = store() else { return };
    for name in store.available_models() {
        let from_meta = store.model_config(&name).unwrap();
        let from_registry = ModelConfig::by_name(&name).unwrap();
        assert_eq!(from_meta, from_registry, "{name}: registry drift");
    }
}

#[test]
fn training_reduced_loss() {
    // The build-time training logs must show a decreasing loss curve —
    // guards against silently-broken training producing noise weights.
    let Some(store) = store() else { return };
    for name in store.available_models() {
        let path = store.dir().join(format!("train_log_{name}.txt"));
        let Ok(text) = std::fs::read_to_string(&path) else { continue };
        let losses: Vec<f64> = text
            .lines()
            .filter_map(|l| l.split_whitespace().nth(1)?.parse().ok())
            .collect();
        assert!(losses.len() >= 50, "{name}: too few steps logged");
        let head: f64 = losses[..10].iter().sum::<f64>() / 10.0;
        let tail: f64 = losses[losses.len() - 10..].iter().sum::<f64>() / 10.0;
        assert!(
            tail < head * 0.9,
            "{name}: loss did not decrease ({head:.3} -> {tail:.3})"
        );
    }
}

#[test]
fn roundtrip_weights_through_rust_writer() {
    // rust writer -> rust reader must reproduce the python-written weights.
    let Some(store) = store() else { return };
    let cfg = store.model_config("nano").unwrap();
    let w = store.weights("nano").unwrap();
    let tmp = std::env::temp_dir().join("lamp_roundtrip_weights.lamp");
    w.to_tensor_file().unwrap().save(&tmp).unwrap();
    let w2 = Weights::load(&tmp, &cfg).unwrap();
    assert_eq!(w.wte, w2.wte);
    assert_eq!(w.blocks[0].w_qkv, w2.blocks[0].w_qkv);
    assert_eq!(w.lnf_b, w2.lnf_b);
    let _ = std::fs::remove_file(tmp);
}
