//! Engine parity: the PR-1 performance paths — pool-tiled attention,
//! scratch-reusing forward, and KV-cache decode — must reproduce the
//! sequential reference engine bit-for-bit (deterministic rules) or
//! statistically (Random rule), per the contract in DESIGN.md
//! §Bit-exactness.

use lamp::coordinator::{Engine, NativeEngine, PrecisionPolicy, Rule};
use lamp::lamp::softmax::SoftmaxRule;
use lamp::linalg::Matrix;
use lamp::model::{
    forward, generate, generate_reforward, AttentionPrecision, Decode, DecodeSession,
    ModelConfig, Weights,
};
use lamp::util::{Rng, ThreadPool};

fn small_weights(seed: u64) -> Weights {
    let mut rng = Rng::new(seed);
    Weights::random(&ModelConfig::small(), &mut rng).unwrap()
}

fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
    a.shape() == b.shape()
        && a.data()
            .iter()
            .zip(b.data())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn parallel_attention_bit_identical_all_rules() {
    // (head, row)-tiled attention over the pool vs the sequential loop, on
    // a 4-layer model through the full forward pass, at μ=23 (the
    // acceptance setting) and low precision, for every selection rule.
    let w = small_weights(1);
    let pool = ThreadPool::new(4);
    let tokens: Vec<u32> = (0..48).map(|i| (i * 31 + 7) % 512).collect();
    let rules = [
        SoftmaxRule::Strict,
        SoftmaxRule::Relaxed,
        SoftmaxRule::RelaxedLengthNorm { ref_len: 128 },
        SoftmaxRule::Random,
    ];
    let mut precs = vec![AttentionPrecision::reference(), AttentionPrecision::uniform(4)];
    for rule in rules {
        precs.push(AttentionPrecision::lamp(4, 0.05, rule));
    }
    for prec in precs {
        let seq = forward(&w, &tokens, prec, 11).unwrap();
        let mut scratch = lamp::model::ForwardScratch::new();
        let par =
            lamp::model::forward_with(&w, &tokens, prec, 11, &mut scratch, Some(&pool))
                .unwrap();
        assert!(
            bits_equal(&seq.logits, &par.logits),
            "parallel forward diverges at mu={} tau={} rule={:?}",
            prec.mu,
            prec.tau,
            prec.rule
        );
        assert_eq!(seq.stats.recomputed, par.stats.recomputed);
        assert_eq!(seq.stats.per_layer, par.stats.per_layer);
    }
}

#[test]
fn kv_decode_bit_identical_to_reforward_at_mu23() {
    // Acceptance criterion: KV-cache decode is bit-identical to the full
    // re-forward loop under AttentionPrecision::reference() (μ=23).
    let w = small_weights(2);
    let prompt: Vec<u32> = (0..12).map(|i| (i * 13 + 3) % 512).collect();
    let prec = AttentionPrecision::reference();
    let (kv, kv_rate) = generate(&w, &prompt, 24, prec, Decode::Greedy, 9).unwrap();
    let (rf, rf_rate) = generate_reforward(&w, &prompt, 24, prec, Decode::Greedy, 9).unwrap();
    assert_eq!(kv, rf);
    assert_eq!(kv_rate, 0.0);
    assert_eq!(rf_rate, 0.0);

    // Stronger: every decoded position's logits equal the full pass row.
    let mut session = DecodeSession::new(&w, prec, 9);
    session.prefill(&kv).unwrap();
    let full = forward(&w, &kv, prec, 9).unwrap();
    let last = full.logits.row(kv.len() - 1);
    for (a, b) in session.logits().iter().zip(last) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn kv_decode_consistent_under_lamp_policies() {
    // Deterministic LAMP rules: bit-identical token streams. Random rule:
    // identical streams too (position-keyed RNG) plus statistically
    // consistent recompute rates against the strict budget.
    let w = small_weights(3);
    let prompt: Vec<u32> = (0..8).map(|i| (i * 29 + 1) % 512).collect();
    for rule in [SoftmaxRule::Strict, SoftmaxRule::Relaxed, SoftmaxRule::Random] {
        let prec = AttentionPrecision::lamp(4, 0.05, rule);
        let (kv, kv_rate) = generate(&w, &prompt, 16, prec, Decode::Greedy, 21).unwrap();
        let (rf, _) = generate_reforward(&w, &prompt, 16, prec, Decode::Greedy, 21).unwrap();
        assert_eq!(kv, rf, "{rule:?}");
        assert!((0.0..1.0).contains(&kv_rate), "{rule:?}: rate={kv_rate}");
    }
    // Random's budget tracks strict's on the same scores.
    let strict = AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Strict);
    let random = AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Random);
    let mut s1 = DecodeSession::new(&w, strict, 5);
    let mut s2 = DecodeSession::new(&w, random, 5);
    let stream: Vec<u32> = (0..32).map(|i| (i * 17 + 11) % 512).collect();
    s1.prefill(&stream).unwrap();
    s2.prefill(&stream).unwrap();
    let (a, b) = (s1.stats().recomputed as f64, s2.stats().recomputed as f64);
    assert!(
        (a - b).abs() <= 0.25 * a.max(32.0),
        "random budget drifted: strict={a} random={b}"
    );
}

#[test]
fn parallel_engine_matches_sequential_engine() {
    // Coordinator-level wiring: a pool-backed NativeEngine serves the same
    // logits as the plain one.
    let mut rng = Rng::new(4);
    let w = Weights::random(&ModelConfig::nano(), &mut rng).unwrap();
    let seq_engine = NativeEngine::new(w.clone());
    let par_engine = NativeEngine::new(w).with_threads(4);
    let batch: Vec<Vec<u32>> = (0..4)
        .map(|b| (0..20).map(|i| ((b * 41 + i * 7 + 2) % 128) as u32).collect())
        .collect();
    for policy in [
        PrecisionPolicy::reference(),
        PrecisionPolicy::uniform(4),
        PrecisionPolicy::lamp(4, 0.05, Rule::Strict),
        PrecisionPolicy::lamp(4, 0.05, Rule::Random),
    ] {
        let a = seq_engine.infer(&batch, &policy, 7).unwrap();
        let b = par_engine.infer(&batch, &policy, 7).unwrap();
        assert_eq!(a.logits.len(), b.logits.len());
        for (x, y) in a.logits.iter().zip(&b.logits) {
            assert!(bits_equal(x, y), "engine outputs diverge under {policy:?}");
        }
        assert_eq!(a.stats.recomputed, b.stats.recomputed, "{policy:?}");
    }
}

#[test]
fn decode_does_asymptotically_less_work() {
    // Not a wall-clock benchmark (CI machines jitter) — count the causal
    // products instead: generating T tokens after an S-token prompt
    // evaluates each product exactly once in the session, vs once per pass
    // in the re-forward loop. The per-pass forward counts its full
    // triangle, so the session's total must be strictly smaller once more
    // than one token is generated.
    let w = small_weights(5);
    let prompt: Vec<u32> = (0..16).collect();
    let prec = AttentionPrecision::uniform(4);
    let mut session = DecodeSession::new(&w, prec, 0);
    session.prefill(&prompt).unwrap();
    for t in 0..24u32 {
        session.decode_step(t % 512).unwrap();
    }
    let cfg = &w.config;
    let n = prompt.len() + 24;
    assert_eq!(
        session.stats().causal_total,
        cfg.layers * cfg.heads * n * (n + 1) / 2,
        "each product evaluated exactly once"
    );
    // The re-forward loop would have evaluated sum_{s=16..39} of full
    // triangles — an order of magnitude more products.
    let reforward_products: usize = (prompt.len()..n)
        .map(|s| cfg.layers * cfg.heads * s * (s + 1) / 2)
        .sum();
    assert!(session.stats().causal_total * 4 < reforward_products);
}

#[test]
fn paged_f32_cache_bit_identical_to_contiguous_for_every_plan() {
    // The PR-5 acceptance pin: f32-backed paging (any block size, shared
    // pool, sharing on) reproduces the pre-refactor contiguous cache —
    // whose semantics the full forward pass retains — bit for bit under
    // every PrecisionPlan, including whole-model Random-rule plans.
    use lamp::model::{
        forward, KvBlockPool, KvCacheOptions, PrecisionPlan, SitePrecision, Weights,
    };
    use lamp::linalg::WeightFormat;
    let mut rng = Rng::new(51);
    let w = Weights::random(&ModelConfig::nano(), &mut rng).unwrap();
    let cfg = &w.config;
    let tokens: Vec<u32> = (0..17).map(|i| (i * 13 + 4) % 128).collect();
    let plans: Vec<PrecisionPlan> = vec![
        PrecisionPlan::reference(),
        AttentionPrecision::uniform(3).into(),
        AttentionPrecision::lamp(3, 0.05, SoftmaxRule::Random).into(),
        PrecisionPlan::whole_model(SitePrecision::lamp(3, 0.1, SoftmaxRule::Strict)),
        PrecisionPlan::whole_model(SitePrecision::lamp(4, 0.1, SoftmaxRule::Random)),
    ];
    for block_size in [1usize, 3, 5, 16] {
        let pool = KvBlockPool::new(
            cfg,
            KvCacheOptions {
                format: WeightFormat::F32,
                repair_tau: f32::INFINITY,
                block_size,
                capacity_blocks: cfg.seq.div_ceil(block_size) * 2,
                sharing: true,
            },
        )
        .unwrap();
        for &plan in &plans {
            let mut session = DecodeSession::with_pool(&w, plan, 9, pool.clone());
            for (i, &t) in tokens.iter().enumerate() {
                session.decode_step(t).unwrap();
                let full = forward(&w, &tokens[..=i], plan, 9).unwrap();
                for (c, (a, b)) in
                    session.logits().iter().zip(full.logits.row(i)).enumerate()
                {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "bs={block_size} step {i} col {c} diverges under {plan:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn speculative_engine_decode_bit_identical_and_pool_settles() {
    // The PR-9 acceptance pin at the engine level: under every
    // (draft plan, k) the speculative stream equals the solo target-plan
    // stream bit for bit, and the rollback-heavy draft traffic leaves the
    // shared block pool empty once the session retires.
    use lamp::coordinator::{SitePolicy, SpecPolicy};
    use lamp::linalg::WeightFormat;
    use lamp::model::KvCacheOptions;

    let mut rng = Rng::new(61);
    let w = Weights::random(&ModelConfig::nano(), &mut rng).unwrap();
    let cfg = w.config.clone();
    let target = PrecisionPolicy::lamp(3, 0.1, Rule::Strict);
    let prompt: Vec<u32> = (0..6).map(|i| (i * 17 + 3) % 128).collect();
    let solo_engine = NativeEngine::new(w.clone());
    let (solo, _) = solo_engine.generate(&prompt, 16, &target, Decode::Greedy, 11).unwrap();

    let engine = NativeEngine::new(w)
        .with_kv_cache(KvCacheOptions::serving(&cfg, WeightFormat::F32, 2))
        .unwrap();
    for draft_mu in [1u32, 2, 3] {
        for k in [1usize, 2, 4, 8] {
            let spec = target
                .with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(draft_mu), k)));
            spec.validate().unwrap();
            let mut session = engine.decode_session(&spec, 11).unwrap();
            let (tokens, stats) =
                lamp::model::generate_with_session(&mut session, &prompt, 16, Decode::Greedy)
                    .unwrap();
            drop(session);
            assert_eq!(tokens, solo, "stream diverges at draft mu={draft_mu} k={k}");
            assert!(stats.spec.rounds > 0, "draft mu={draft_mu} k={k} never speculated");
            assert_eq!(
                engine.kv_pool().unwrap().stats().used_blocks,
                0,
                "draft mu={draft_mu} k={k} leaked pool blocks"
            );
        }
    }
}

#[test]
fn speculative_parity_holds_on_quantized_kv_pools() {
    // The accepted prefix is re-realized under the *target* session's KV
    // format and repair threshold, never the draft's scratch state — so
    // speculation composes with quantized paged KV: spec and solo sessions
    // over identically-configured pools emit identical streams, and both
    // pools drain to zero used blocks when the sessions drop.
    use lamp::linalg::WeightFormat;
    use lamp::model::{
        generate_with_session, KvBlockPool, KvCacheOptions, PrecisionPlan, SpecConfig,
    };

    let mut rng = Rng::new(62);
    let w = Weights::random(&ModelConfig::nano(), &mut rng).unwrap();
    let cfg = &w.config;
    let target =
        PrecisionPlan::whole_model(AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Strict));
    let spec_plan =
        target.with_spec(Some(SpecConfig::whole_model(AttentionPrecision::uniform(2), 3)));
    let prompt: Vec<u32> = (0..5).map(|i| (i * 23 + 2) % 128).collect();
    for fmt in [WeightFormat::F32, WeightFormat::Bf16, WeightFormat::PsRounded { mu: 3 }] {
        let mk_pool = || {
            KvBlockPool::new(
                cfg,
                KvCacheOptions {
                    format: fmt,
                    repair_tau: 0.05,
                    block_size: 4,
                    capacity_blocks: cfg.seq.div_ceil(4) * 2,
                    sharing: false,
                },
            )
            .unwrap()
        };
        let (pool_a, pool_b) = (mk_pool(), mk_pool());
        let mut solo = DecodeSession::with_pool(&w, target, 13, pool_a.clone());
        let (a, _) = generate_with_session(&mut solo, &prompt, 14, Decode::Greedy).unwrap();
        let mut spec = DecodeSession::with_pool(&w, spec_plan, 13, pool_b.clone());
        let (b, stats) =
            generate_with_session(&mut spec, &prompt, 14, Decode::Greedy).unwrap();
        assert_eq!(a, b, "{fmt:?}: speculative stream diverges on quantized KV");
        assert!(stats.spec.rounds > 0, "{fmt:?}: speculation never ran");
        drop(solo);
        drop(spec);
        assert_eq!(pool_a.stats().used_blocks, 0, "{fmt:?}: solo pool leaked");
        assert_eq!(pool_b.stats().used_blocks, 0, "{fmt:?}: spec pool leaked");
    }
}

#[test]
fn quantized_kv_repair_ladder_tau_zero_exact_uniform_bounded() {
    // The LAMP-repaired quantized KV contract: repair_tau = 0 pins every
    // inexact cached row at f32, making decode bit-identical to the f32
    // cache; tau = inf (uniform quantized) deviates; a finite tau pins a
    // fraction of rows and lands at least as close as uniform.
    use lamp::model::{KvBlockPool, KvCacheOptions, Weights};
    use lamp::linalg::WeightFormat;
    let mut rng = Rng::new(52);
    let w = Weights::random(&ModelConfig::nano(), &mut rng).unwrap();
    let cfg = &w.config;
    let tokens: Vec<u32> = (0..20).map(|i| (i * 11 + 6) % 128).collect();
    let prec = AttentionPrecision::reference();

    let mut oracle = DecodeSession::new(&w, prec, 3);
    oracle.prefill(&tokens).unwrap();
    let exact: Vec<f32> = oracle.logits().to_vec();

    for fmt in [WeightFormat::Bf16, WeightFormat::PsRounded { mu: 3 }] {
        let run = |tau: f32| {
            let pool = KvBlockPool::new(
                cfg,
                KvCacheOptions {
                    format: fmt,
                    repair_tau: tau,
                    block_size: 4,
                    capacity_blocks: cfg.seq.div_ceil(4),
                    sharing: false,
                },
            )
            .unwrap();
            let mut s = DecodeSession::with_pool(&w, prec, 3, pool);
            s.prefill(&tokens).unwrap();
            let pinned = s.kv().pinned_rate();
            (s.logits().to_vec(), pinned)
        };
        // tau = 0: every inexact row pinned — bitwise equal to f32 KV.
        let (repaired_all, rate_all) = run(0.0);
        assert!(rate_all > 0.9, "{fmt:?}: tau=0 must pin ~every row, got {rate_all}");
        for (c, (a, b)) in repaired_all.iter().zip(&exact).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{fmt:?} col {c}: tau=0 repair must be exact"
            );
        }
        // tau = inf: uniform quantized KV must actually perturb logits.
        let (uniform, rate_uni) = run(f32::INFINITY);
        assert_eq!(rate_uni, 0.0);
        assert!(
            uniform.iter().zip(&exact).any(|(a, b)| a.to_bits() != b.to_bits()),
            "{fmt:?}: uniform quantized KV left logits bit-identical"
        );
        let mean_err = |v: &[f32]| -> f64 {
            v.iter()
                .zip(&exact)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / exact.len() as f64
        };
        assert!(mean_err(&uniform) > 0.0);
        assert_eq!(mean_err(&repaired_all), 0.0);
    }
}
