//! Property-based tests over the crate's core invariants, via the
//! hand-rolled `lamp::check` framework (offline stand-in for proptest).

use lamp::check::{forall, pair, Config, Gen};
use lamp::coordinator::{Batcher, InferenceRequest, PrecisionPolicy, Rule};
use lamp::lamp::activation::{kappa_c_activation, select_activation, Activation};
use lamp::lamp::rmsnorm::{kappa_c_rmsnorm, select_rmsnorm};
use lamp::lamp::softmax::{kappa1_softmax, select_strict, softmax};
use lamp::softfloat::round::{
    round_to_mantissa, round_to_mantissa_stochastic, ulp_at, unit_roundoff,
};
use lamp::softfloat::dot::{dot_f32, dot_ps};
use lamp::util::Rng;
use std::time::Duration;

#[test]
fn prop_rounding_idempotent() {
    forall(
        Config::default().cases(2000),
        pair(Gen::f32_range(-1e6, 1e6), Gen::u32_range(1, 23)),
        |&(x, mu)| {
            let r = round_to_mantissa(x, mu);
            round_to_mantissa(r, mu).to_bits() == r.to_bits()
        },
    );
}

#[test]
fn prop_rounding_monotone() {
    // x <= y  =>  round(x) <= round(y)
    forall(
        Config::default().cases(2000),
        pair(
            pair(Gen::f32_range(-1e4, 1e4), Gen::f32_range(-1e4, 1e4)),
            Gen::u32_range(1, 23),
        ),
        |&((x, y), mu)| {
            let (lo, hi) = if x <= y { (x, y) } else { (y, x) };
            round_to_mantissa(lo, mu) <= round_to_mantissa(hi, mu)
        },
    );
}

#[test]
fn prop_rounding_error_within_unit_roundoff() {
    forall(
        Config::default().cases(2000),
        pair(Gen::f32_range(-1e4, 1e4), Gen::u32_range(1, 23)),
        |&(x, mu)| {
            if x == 0.0 {
                return true;
            }
            let r = round_to_mantissa(x, mu) as f64;
            ((r - x as f64) / x as f64).abs() <= unit_roundoff(mu) * (1.0 + 1e-9)
        },
    );
}

#[test]
fn prop_dot_ps_error_bound() {
    // First-order bound: |dot_ps − dot_exact| ≤ 2·k·u·Σ|aᵢbᵢ|.
    forall(
        Config::default().cases(300),
        pair(
            pair(
                Gen::f32_vec(1, 64, -2.0, 2.0),
                Gen::u32_range(2, 23),
            ),
            Gen::u32_range(0, u32::MAX / 2),
        ),
        |&((ref a, mu), seed)| {
            let mut rng = Rng::new(seed as u64);
            let b: Vec<f32> = a.iter().map(|_| rng.f32() * 4.0 - 2.0).collect();
            let exact: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = dot_ps(a, &b, mu) as f64;
            let mag: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| (x as f64 * y as f64).abs())
                .sum();
            (got - exact).abs() <= 2.0 * a.len() as f64 * unit_roundoff(mu) * mag + 1e-10
        },
    );
}

#[test]
fn prop_dot_ps23_equals_fp32() {
    forall(
        Config::default().cases(500),
        pair(Gen::f32_vec(0, 48, -3.0, 3.0), Gen::u32_range(0, u32::MAX / 2)),
        |&(ref a, seed)| {
            let mut rng = Rng::new(seed as u64);
            let b: Vec<f32> = a.iter().map(|_| rng.f32() * 6.0 - 3.0).collect();
            dot_ps(a, &b, 23).to_bits() == dot_f32(a, &b).to_bits()
        },
    );
}

#[test]
fn prop_strict_selection_achieves_tau() {
    // The defining guarantee of eq. (8): κ₁ ≤ τ after selection.
    forall(
        Config::default().cases(1000),
        pair(Gen::f32_vec(1, 64, -12.0, 12.0), Gen::f32_range(0.0, 1.0)),
        |&(ref y, tau)| {
            let mask = select_strict(y, tau);
            kappa1_softmax(y, &mask) <= tau
        },
    );
}

#[test]
fn prop_strict_selection_minimal() {
    // No selected index is redundant.
    forall(
        Config::default().cases(300),
        pair(Gen::f32_vec(2, 24, -8.0, 8.0), Gen::f32_range(0.01, 0.5)),
        |&(ref y, tau)| {
            let mask = select_strict(y, tau);
            (0..y.len()).all(|j| {
                if !mask[j] {
                    return true;
                }
                let mut weaker = mask.clone();
                weaker[j] = false;
                kappa1_softmax(y, &weaker) > tau
            })
        },
    );
}

#[test]
fn prop_softmax_is_distribution() {
    forall(
        Config::default().cases(1000),
        Gen::f32_vec(1, 64, -40.0, 40.0),
        |y| {
            let z = softmax(y);
            let sum: f32 = z.iter().sum();
            z.iter().all(|&p| (0.0..=1.0 + 1e-6).contains(&p)) && (sum - 1.0).abs() < 1e-4
        },
    );
}

#[test]
fn prop_rmsnorm_greedy_feasible() {
    forall(
        Config::default().cases(500),
        pair(Gen::f32_vec(1, 32, -5.0, 5.0), Gen::f32_range(0.0, 2.0)),
        |&(ref y, tau)| {
            let mask = select_rmsnorm(y, tau as f64);
            kappa_c_rmsnorm(y, &mask) <= tau as f64 + 1e-9
        },
    );
}

/// The strict-LAMP κ₁ bound of Prop 3.3, evaluated against an f64 softmax
/// reference (the test-side forward-error oracle: κ bounds the ℓ₁-normwise
/// relative error the unselected low-precision products can induce).
fn kappa1_softmax_f64(y: &[f32], selected: &[bool]) -> f64 {
    assert_eq!(y.len(), selected.len());
    let m = y.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b as f64));
    let exps: Vec<f64> = y.iter().map(|&v| (v as f64 - m).exp()).collect();
    let sum: f64 = exps.iter().sum();
    let mut k = 0.0f64;
    for j in 0..y.len() {
        if !selected[j] {
            let z = exps[j] / sum;
            k = k.max(2.0 * z * (1.0 - z) * (y[j] as f64).abs());
        }
    }
    k
}

#[test]
fn prop_softmax_recompute_monotone_tightening_tau_never_hurts() {
    // Recompute monotonicity for the strict softmax rule: tightening the
    // condition threshold selects a superset of products, so the forward-
    // error bound κ₁ vs the f64 reference never increases. Both the mask
    // nesting and the bound monotonicity are asserted.
    forall(
        Config::default().cases(600),
        pair(
            Gen::f32_vec(1, 48, -10.0, 10.0),
            pair(Gen::f32_range(0.0, 0.5), Gen::f32_range(0.0, 0.5)),
        ),
        |&(ref y, (t1, t2))| {
            let (lo, hi) = if t1 <= t2 { (t1, t2) } else { (t2, t1) };
            let m_lo = select_strict(y, lo);
            let m_hi = select_strict(y, hi);
            let nested = m_hi.iter().zip(&m_lo).all(|(&h, &l)| !h || l);
            nested && kappa1_softmax_f64(y, &m_lo) <= kappa1_softmax_f64(y, &m_hi)
        },
    );
}

#[test]
fn prop_activation_selection_achieves_tau() {
    // The closed-form activation selection (§3.1) satisfies its defining
    // bound: the max unselected diagonal sensitivity never exceeds τ.
    forall(
        Config::default().cases(600),
        pair(Gen::f32_vec(1, 48, -6.0, 6.0), Gen::f32_range(0.0, 2.0)),
        |&(ref y, tau)| {
            for act in [Activation::Gelu, Activation::Tanh, Activation::Silu] {
                let mask = select_activation(y, act, tau);
                if kappa_c_activation(y, act, &mask) > tau {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_activation_recompute_monotone_tightening_tau_never_hurts() {
    // Whole-model extension of the PR-2 monotonicity properties to the
    // activation site: tightening τ selects a superset of hidden units
    // (thresholding is monotone), so the site's measured forward-error
    // bound κ_c — the max sensitivity left unrepaired — never increases.
    forall(
        Config::default().cases(600),
        pair(
            Gen::f32_vec(1, 48, -6.0, 6.0),
            pair(Gen::f32_range(0.0, 2.0), Gen::f32_range(0.0, 2.0)),
        ),
        |&(ref y, (t1, t2))| {
            let (lo, hi) = if t1 <= t2 { (t1, t2) } else { (t2, t1) };
            let m_lo = select_activation(y, Activation::Gelu, lo);
            let m_hi = select_activation(y, Activation::Gelu, hi);
            let nested = m_hi.iter().zip(&m_lo).all(|(&h, &l)| !h || l);
            nested
                && kappa_c_activation(y, Activation::Gelu, &m_lo)
                    <= kappa_c_activation(y, Activation::Gelu, &m_hi)
        },
    );
}

#[test]
fn prop_rmsnorm_recompute_monotone_tightening_tau_never_hurts() {
    // Same monotonicity for the greedy RMS-norm selection (Prop 3.2): a
    // tighter τ keeps a longer prefix of the same sorted order, and κ_c
    // over the shrunken unselected set cannot grow.
    forall(
        Config::default().cases(400),
        pair(
            Gen::f32_vec(1, 24, -5.0, 5.0),
            pair(Gen::f32_range(0.0, 1.5), Gen::f32_range(0.0, 1.5)),
        ),
        |&(ref y, (t1, t2))| {
            let (lo, hi) = if t1 <= t2 { (t1, t2) } else { (t2, t1) };
            let m_lo = select_rmsnorm(y, lo as f64);
            let m_hi = select_rmsnorm(y, hi as f64);
            kappa_c_rmsnorm(y, &m_lo) <= kappa_c_rmsnorm(y, &m_hi) + 1e-12
        },
    );
}

#[test]
fn prop_stochastic_rounding_bounds() {
    // round_to_mantissa_stochastic over generated mantissa widths: the
    // result is always one of the two PS(μ)-representable neighbours —
    // within one ulp of the input, low bits cleared, magnitude bracketing
    // the input — and exactly representable values never move.
    forall(
        Config::default().cases(1500),
        pair(
            pair(Gen::f32_range(-1e4, 1e4), Gen::u32_range(1, 23)),
            Gen::u32_range(0, u32::MAX / 2),
        ),
        |&((x, mu), seed)| {
            let mut rng = Rng::new(seed as u64);
            let r = round_to_mantissa_stochastic(x, mu, &mut rng);
            if mu == 23 {
                return r.to_bits() == x.to_bits();
            }
            let shift = 23 - mu;
            let down = f32::from_bits((x.to_bits() >> shift) << shift);
            let up = f32::from_bits(((x.to_bits() >> shift) + 1) << shift);
            // One of the two neighbours, never anything else.
            if r.to_bits() != down.to_bits() && r.to_bits() != up.to_bits() {
                return false;
            }
            // Low mantissa bits cleared; within one PS(μ) ulp; magnitude
            // brackets the input (bit-truncation rounds toward zero).
            let low = r.to_bits() & ((1u32 << shift) - 1);
            // The one-ulp bound is checked for normal inputs (ulp_at models
            // PS(μ) spacing; shrinking can probe subnormals, where the two-
            // neighbour check above is already the complete bound).
            let within_ulp =
                x.abs() < f32::MIN_POSITIVE || (r - x).abs() < ulp_at(x, mu) * 1.000001;
            low == 0 && within_ulp && down.abs() <= x.abs() && x.abs() <= up.abs()
        },
    );
}

#[test]
fn prop_stochastic_rounding_fixes_representables() {
    forall(
        Config::default().cases(800),
        pair(
            pair(Gen::f32_range(-1e4, 1e4), Gen::u32_range(1, 22)),
            Gen::u32_range(0, u32::MAX / 2),
        ),
        |&((x, mu), seed)| {
            let mut rng = Rng::new(seed as u64);
            let fixed = round_to_mantissa(x, mu);
            round_to_mantissa_stochastic(fixed, mu, &mut rng).to_bits() == fixed.to_bits()
        },
    );
}

#[test]
fn prop_batcher_conserves_requests() {
    // Everything pushed is eventually cut exactly once, FIFO per policy.
    forall(
        Config::default().cases(200),
        pair(Gen::usize_range(1, 40), Gen::u32_range(0, u32::MAX / 2)),
        |&(n, seed)| {
            let mut rng = Rng::new(seed as u64);
            let mut batcher = Batcher::new(4, Duration::from_secs(3600));
            let mut pushed = Vec::new();
            for id in 0..n as u64 {
                let mu = [2u32, 4, 7][rng.range(0, 3)];
                let policy = PrecisionPolicy::uniform(mu);
                batcher.push(InferenceRequest::new(id, vec![1, 2], policy));
                pushed.push(id);
            }
            let mut seen = Vec::new();
            while let Some(cut) = batcher.cut(true) {
                for (r, _) in cut.requests {
                    seen.push(r.id);
                }
            }
            seen.sort_unstable();
            seen == pushed && batcher.pending() == 0
        },
    );
}

#[test]
fn prop_policy_tier_roundtrip_rules() {
    for rule in [
        Rule::Strict,
        Rule::Relaxed,
        Rule::RelaxedLengthNorm,
        Rule::Random,
        Rule::Tile { width: 4 },
        Rule::TileRandom { width: 9 },
    ] {
        assert_eq!(Rule::by_name(&rule.name()).unwrap(), rule);
    }
}

#[test]
fn prop_bf16_roundtrip_exact_and_error_bounded() {
    use lamp::linalg::tensor::{bf16_to_f32, f32_to_bf16};
    forall(
        Config::default().cases(2000),
        Gen::f32_range(-1e6, 1e6),
        |&x| {
            let q = bf16_to_f32(f32_to_bf16(x));
            // Dequantization is exact: narrowing the widened value is the
            // identity (quantize ∘ dequantize ∘ quantize = quantize) ...
            let idempotent = f32_to_bf16(q) == f32_to_bf16(x);
            // ... and the one-time narrowing error is ≤ 1 ulp at 7
            // mantissa bits (RNE actually guarantees half an ulp).
            let bounded = (q - x).abs() <= ulp_at(x, 7);
            idempotent && bounded
        },
    );
}

#[test]
fn prop_ps_storage_rounding_error_bounded_at_mu() {
    // The PS(μ)-rounded storage format's contract: |q - x| ≤ 1 ulp at μ.
    forall(
        Config::default().cases(2000),
        pair(Gen::f32_range(-1e6, 1e6), Gen::u32_range(1, 23)),
        |&(x, mu)| {
            let q = round_to_mantissa(x, mu);
            (q - x).abs() <= ulp_at(x, mu)
        },
    );
}

#[test]
fn prop_selection_monotone_in_tau() {
    forall(
        Config::default().cases(400),
        pair(
            Gen::f32_vec(1, 32, -8.0, 8.0),
            pair(Gen::f32_range(0.0, 0.5), Gen::f32_range(0.0, 0.5)),
        ),
        |&(ref y, (t1, t2))| {
            let (lo, hi) = if t1 <= t2 { (t1, t2) } else { (t2, t1) };
            let m_lo = select_strict(y, lo);
            let m_hi = select_strict(y, hi);
            // Higher τ selects a subset.
            m_hi.iter().zip(&m_lo).all(|(&h, &l)| !h || l)
        },
    );
}

// --- Paged KV block-pool allocator properties (PR 5) ---------------------

#[test]
fn prop_kv_block_pool_no_leaks_or_double_frees() {
    // Random admit / grow / preempt (clear) / retire (drop) schedules over
    // a shared pool: allocation never exceeds capacity, buffers are never
    // duplicated (a double free would make free + used overshoot the
    // number of buffers ever created), exhaustion is the clean typed
    // resource error, and releasing everything (plus evicting the prompt
    // cache) returns the pool to exactly zero used blocks.
    use lamp::model::{KvBlockPool, KvCacheOptions, ModelConfig, PagedKvCache};
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(0xB10C);
    for trial in 0..20u64 {
        let mut opts = KvCacheOptions::private(&cfg);
        opts.block_size = rng.range(1, 6);
        opts.capacity_blocks = rng.range(2, 10);
        opts.sharing = rng.below(2) == 0;
        let pool = KvBlockPool::new(&cfg, opts).unwrap();
        let mut sessions: Vec<PagedKvCache> = Vec::new();
        let row = vec![0.5f32; cfg.d_model];
        for _ in 0..rng.range(20, 60) {
            match rng.below(4) {
                0 => sessions.push(PagedKvCache::new(pool.clone(), rng.next_u64())),
                1 if !sessions.is_empty() => {
                    // Retire: Drop must release every block.
                    let i = rng.range(0, sessions.len());
                    sessions.swap_remove(i);
                }
                2 if !sessions.is_empty() => {
                    // Preempt: clear but keep the session for reuse.
                    let i = rng.range(0, sessions.len());
                    sessions[i].clear();
                }
                _ if !sessions.is_empty() => {
                    // Grow by one position across every layer; exhaustion
                    // must be the typed resource error and change nothing.
                    let i = rng.range(0, sessions.len());
                    let pos = sessions[i].len();
                    if pos < cfg.seq {
                        let mut ok = true;
                        for l in 0..cfg.layers {
                            match sessions[i].append_row(l, pos, &row, &row) {
                                Ok(_) => {}
                                Err(e) => {
                                    assert!(e.is_resource(), "unexpected error: {e}");
                                    ok = false;
                                    break;
                                }
                            }
                        }
                        if ok {
                            sessions[i].complete_position((pos % 128) as u32, pos);
                        }
                    }
                }
                _ => {}
            }
            let st = pool.stats();
            assert!(
                st.used_blocks <= st.capacity_blocks,
                "trial {trial}: over-allocated ({} > {})",
                st.used_blocks,
                st.capacity_blocks
            );
            assert!(
                st.free_buffers + st.used_blocks <= st.capacity_blocks,
                "trial {trial}: more buffers than ever created (double free?)"
            );
        }
        sessions.clear();
        pool.evict_unused();
        let st = pool.stats();
        assert_eq!(st.used_blocks, 0, "trial {trial}: leaked blocks");
        assert!(st.free_buffers <= st.capacity_blocks);
    }
}

#[test]
fn prop_kv_prefix_sharing_and_cow_refcounts_settle() {
    // Sessions sharing one chain root over a tiny token alphabet collide
    // on prefixes constantly, exercising publish / adopt / copy-on-write /
    // evict; whatever the schedule, refcounts must settle: releasing every
    // session and evicting the prompt cache returns the pool to empty.
    use lamp::model::{KvBlockPool, KvCacheOptions, ModelConfig, PagedKvCache};
    let cfg = ModelConfig::nano();
    let d = cfg.d_model;
    let mut rng = Rng::new(0x5EED);
    for trial in 0..10u64 {
        let mut opts = KvCacheOptions::private(&cfg);
        opts.block_size = 2;
        opts.capacity_blocks = rng.range(6, 16);
        opts.sharing = true;
        let pool = KvBlockPool::new(&cfg, opts).unwrap();
        let root = 42u64;
        let mut sessions: Vec<(PagedKvCache, Vec<u32>)> = Vec::new();
        let mut adoptions = 0usize;
        for _ in 0..60 {
            let roll = rng.below(3);
            if roll == 0 || sessions.is_empty() {
                let toks: Vec<u32> =
                    (0..rng.range(2, 10)).map(|_| rng.below(2) as u32).collect();
                let mut c = PagedKvCache::new(pool.clone(), root);
                adoptions += c.adopt_prefix(&toks[..toks.len() - 1]);
                sessions.push((c, toks));
            } else if roll == 1 {
                let i = rng.range(0, sessions.len());
                sessions.swap_remove(i);
            } else {
                let i = rng.range(0, sessions.len());
                let (c, toks) = &mut sessions[i];
                let pos = c.len();
                if pos < toks.len() {
                    // Rows are a deterministic function of (pos, layer),
                    // mirroring real decode determinism, so adopted
                    // content always equals what would be recomputed.
                    let row: Vec<f32> =
                        (0..d).map(|k| (pos * 31 + k) as f32 * 0.01).collect();
                    let mut ok = true;
                    for l in 0..cfg.layers {
                        let lrow: Vec<f32> = row.iter().map(|x| x + l as f32).collect();
                        if let Err(e) = c.append_row(l, pos, &lrow, &lrow) {
                            assert!(e.is_resource(), "unexpected error: {e}");
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        c.complete_position(toks[pos], pos);
                    }
                }
            }
            let st = pool.stats();
            assert!(st.used_blocks <= st.capacity_blocks, "trial {trial}: over-allocated");
        }
        sessions.clear();
        pool.evict_unused();
        assert_eq!(
            pool.stats().used_blocks,
            0,
            "trial {trial}: prefix-share refcounts leaked"
        );
        // The tiny alphabet makes prefix collisions overwhelmingly likely
        // across 10 trials; count them across trials rather than per trial.
        let _ = adoptions;
    }
}

// --- SIMD kernels & tile-granular LAMP (PR 8) -----------------------------

/// Serializes tests that toggle the process-global SIMD dispatch mode.
/// The toggled state is observationally benign (SIMD and the scalar replay
/// are bit-identical — that is what these tests prove), but two toggling
/// tests running concurrently could each observe the other's mode.
static SIMD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn prop_simd_dot_and_score_row_match_scalar_replay_on_ragged_tails() {
    use lamp::linalg::set_simd_enabled;
    use lamp::linalg::simd::{dot_block, dot_block_scalar};
    use lamp::softfloat::dot::score_row_ps;
    let _g = SIMD_LOCK.lock().unwrap();
    // dot_block: the vector path, the dispatcher forced scalar, and the
    // named scalar replay agree bit-for-bit at every tail shape (lengths
    // crossing the 8-lane and 32-element block boundaries).
    forall(
        Config::default().cases(150),
        pair(Gen::usize_range(0, 140), Gen::u32_range(0, u32::MAX / 2)),
        |&(k, seed)| {
            let mut rng = Rng::new(seed as u64);
            let a: Vec<f32> = (0..k).map(|_| rng.f32() * 4.0 - 2.0).collect();
            let b: Vec<f32> = (0..k).map(|_| rng.f32() * 4.0 - 2.0).collect();
            set_simd_enabled(true);
            let fast = dot_block(&a, &b);
            set_simd_enabled(false);
            let forced = dot_block(&a, &b);
            let replay = dot_block_scalar(&a, &b);
            set_simd_enabled(true);
            fast.to_bits() == forced.to_bits() && forced.to_bits() == replay.to_bits()
        },
    );
    // score_row_ps: the 8-chain vector body vs the scalar interleave are
    // bit-identical per score (each score is one independent PS chain).
    forall(
        Config::default().cases(100),
        pair(
            pair(Gen::usize_range(1, 80), Gen::usize_range(1, 20)),
            Gen::u32_range(0, u32::MAX / 2),
        ),
        |&((hd, n), seed)| {
            let mut rng = Rng::new(seed as u64);
            let q: Vec<f32> = (0..hd).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let keys: Vec<f32> = (0..hd * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
            for mu in [2u32, 7, 23] {
                let mut va = vec![0.0f32; n];
                let mut vb = vec![0.0f32; n];
                set_simd_enabled(true);
                score_row_ps(&q, &keys, hd, n, mu, 0.25, &mut va);
                set_simd_enabled(false);
                score_row_ps(&q, &keys, hd, n, mu, 0.25, &mut vb);
                if va.iter().zip(&vb).any(|(x, y)| x.to_bits() != y.to_bits()) {
                    set_simd_enabled(true);
                    return false;
                }
            }
            set_simd_enabled(true);
            true
        },
    );
}

#[test]
fn prop_simd_scalar_forward_parity_every_weight_format_and_site() {
    // The whole-model invariant behind LAMP_SIMD=0: a full forward pass —
    // every plan site active, every weight-storage format — is bitwise
    // identical with SIMD dispatch on and off, including the tile rules
    // and their recompute/tile accounting.
    use lamp::linalg::{set_simd_enabled, WeightFormat};
    use lamp::model::{forward, ModelConfig, Weights};
    let _g = SIMD_LOCK.lock().unwrap();
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(0x51AD);
    let base = Weights::random(&cfg, &mut rng).unwrap();
    let tokens: Vec<u32> = (0..12).map(|_| rng.below(cfg.vocab) as u32).collect();
    let policies = [
        PrecisionPolicy::reference(),
        PrecisionPolicy::whole_model(4, 0.1, Rule::Strict),
        PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed),
        PrecisionPolicy::lamp(3, 0.05, Rule::Tile { width: 4 }),
        PrecisionPolicy::lamp(3, 0.05, Rule::TileRandom { width: 4 }),
    ];
    for fmt in [WeightFormat::F32, WeightFormat::Bf16, WeightFormat::PsRounded { mu: 8 }] {
        let w = base.quantize_to(fmt).unwrap();
        for policy in &policies {
            let plan = policy.to_plan(cfg.seq);
            set_simd_enabled(true);
            let a = forward(&w, &tokens, plan, 7).unwrap();
            set_simd_enabled(false);
            let b = forward(&w, &tokens, plan, 7).unwrap();
            let label = policy.label();
            assert_eq!(a.stats.recomputed, b.stats.recomputed, "{fmt:?} {label}");
            assert_eq!(a.stats.tiles, b.stats.tiles, "{fmt:?} {label}");
            assert_eq!(a.stats.mlp, b.stats.mlp, "{fmt:?} {label}");
            assert_eq!(a.stats.sampler, b.stats.sampler, "{fmt:?} {label}");
            for (x, y) in a.logits.data().iter().zip(b.logits.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{fmt:?} {label}: logits diverge");
            }
        }
    }
    set_simd_enabled(true);
}

#[test]
fn prop_simd_scalar_decode_parity_every_kv_format() {
    // Same invariant through the paged-KV decode path, per KV storage
    // format: prefill logits and LAMP accounting are mode-independent.
    use lamp::linalg::{set_simd_enabled, WeightFormat};
    use lamp::model::{DecodeSession, KvBlockPool, KvCacheOptions, ModelConfig, Weights};
    let _g = SIMD_LOCK.lock().unwrap();
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(0x4B56);
    let w = Weights::random(&cfg, &mut rng).unwrap();
    let tokens: Vec<u32> = (0..9).map(|_| rng.below(cfg.vocab) as u32).collect();
    let policy = PrecisionPolicy::lamp(4, 0.05, Rule::Tile { width: 4 });
    for fmt in [WeightFormat::F32, WeightFormat::Bf16, WeightFormat::PsRounded { mu: 4 }] {
        let plan = policy.to_plan(cfg.seq);
        let run = |simd: bool| {
            set_simd_enabled(simd);
            let pool =
                KvBlockPool::new(&cfg, KvCacheOptions::serving(&cfg, fmt, 1)).unwrap();
            let mut s = DecodeSession::with_pool(&w, plan, 9, pool);
            s.prefill(&tokens).unwrap();
            (s.logits().to_vec(), s.stats().clone())
        };
        let (la, sa) = run(true);
        let (lb, sb) = run(false);
        assert_eq!(sa.recomputed, sb.recomputed, "{fmt:?}");
        assert_eq!(sa.tiles, sb.tiles, "{fmt:?}");
        assert!(sa.tiles.total > 0, "{fmt:?}: tile rule must account tiles");
        for (x, y) in la.iter().zip(&lb) {
            assert_eq!(x.to_bits(), y.to_bits(), "{fmt:?}: decode logits diverge");
        }
    }
    set_simd_enabled(true);
}

#[test]
fn prop_tile_selection_tau_monotone_and_count_matched_random() {
    // Tile-rule analogues of the PR-2 selection properties: raising τ
    // never selects more tiles (mask nesting + tile-count monotonicity),
    // and the TileRandom baseline matches the tile count exactly while
    // always keeping the diagonal tile.
    use lamp::lamp::softmax::{select_tile, select_tile_random, tile_count};
    forall(
        Config::default().cases(400),
        pair(
            pair(Gen::f32_vec(1, 64, -8.0, 8.0), Gen::usize_range(1, 12)),
            pair(
                pair(Gen::f32_range(0.0, 0.4), Gen::f32_range(0.0, 0.4)),
                Gen::u32_range(0, u32::MAX / 2),
            ),
        ),
        |&((ref y, width), ((t1, t2), seed))| {
            let (lo, hi) = if t1 <= t2 { (t1, t2) } else { (t2, t1) };
            let m_lo = select_tile(y, lo, width);
            let m_hi = select_tile(y, hi, width);
            let nt = tile_count(y.len(), width);
            let count = |m: &[bool]| (0..nt).filter(|&t| m[t * width]).count();
            let nested = m_hi.iter().zip(&m_lo).all(|(&h, &l)| !h || l);
            let mono = count(&m_hi) <= count(&m_lo);
            let mut rng = Rng::new(seed as u64);
            let mr = select_tile_random(y, lo, width, &mut rng);
            let matched = count(&mr) == count(&m_lo);
            let diag = mr[y.len() - 1] && m_lo[y.len() - 1];
            nested && mono && matched && diag
        },
    );
}

// --- Speculative decoding (PR 9) ------------------------------------------

#[test]
fn prop_spec_acceptance_monotone_as_draft_coarsens() {
    // Coarsening the draft plan along a τ ladder at fixed k can only pull
    // the draft's logits further from the target it must anticipate, so
    // the total accepted look-ahead — aggregated over several prompts to
    // wash out per-step argmax luck — is monotone non-increasing down the
    // ladder. Ties are allowed (widely-spaced rungs can saturate at either
    // end: a loose τ that repairs nothing is bitwise the uniform draft),
    // and adjacent rungs get a ±3-token allowance out of ~170 generated:
    // acceptance is measured at token granularity, so two near-tied drafts
    // can flip a couple of argmaxes in either direction without violating
    // the statistical ordering. The output itself is pinned exactly: every
    // rung decodes bit-identically to solo decode under the target plan.
    use lamp::lamp::softmax::SoftmaxRule;
    use lamp::model::{
        generate_with_stats, Decode, ModelConfig, PrecisionPlan, SitePrecision, SpecConfig,
        Weights,
    };
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(0x5BEC);
    let w = Weights::random(&cfg, &mut rng).unwrap();
    let target =
        PrecisionPlan::whole_model(SitePrecision::lamp(4, 0.02, SoftmaxRule::Strict));
    let k = 4usize;
    let ladder = [
        ("lamp(3, tau=0.05)", SitePrecision::lamp(3, 0.05, SoftmaxRule::Strict)),
        ("lamp(3, tau=0.5)", SitePrecision::lamp(3, 0.5, SoftmaxRule::Strict)),
        ("uniform(3)", SitePrecision::uniform(3)),
        ("uniform(2)", SitePrecision::uniform(2)),
    ];
    let new_tokens = 28;
    let prompts: Vec<Vec<u32>> = (0..6u32)
        .map(|p| (0..6u32).map(|j| (p * 19 + j * 7 + 3) % 128).collect())
        .collect();
    let solos: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| {
            generate_with_stats(&w, p, new_tokens, target, Decode::Greedy, 11).unwrap().0
        })
        .collect();
    let mut totals: Vec<(&str, usize)> = Vec::new();
    for (label, draft) in ladder {
        let plan = target.with_spec(Some(SpecConfig::whole_model(draft, k)));
        plan.validate().unwrap();
        let (mut accepted, mut rounds) = (0usize, 0usize);
        for (p, solo) in prompts.iter().zip(&solos) {
            let (toks, stats) =
                generate_with_stats(&w, p, new_tokens, plan, Decode::Greedy, 11).unwrap();
            assert_eq!(&toks, solo, "{label}: speculative stream diverged from solo");
            accepted += stats.spec.accepted;
            rounds += stats.spec.rounds;
        }
        assert!(rounds > 0, "{label}: never speculated");
        totals.push((label, accepted));
    }
    assert!(totals[0].1 > 0, "the finest draft must accept some look-ahead");
    for pair in totals.windows(2) {
        let ((fine, a), (coarse, b)) = (pair[0], pair[1]);
        assert!(
            b <= a + 3,
            "coarsening {fine} -> {coarse} increased aggregate acceptance ({a} -> {b})"
        );
    }
    let (first, best) = totals[0];
    let (last, worst) = totals[totals.len() - 1];
    assert!(
        worst <= best,
        "end to end, {last} ({worst}) must not out-accept {first} ({best})"
    );
}

// --- Workload generators (PR 7) ------------------------------------------

#[test]
fn prop_zipf_pmf_is_a_distribution_on_its_support() {
    // For any (n, s): pmf sums to ~1 over [0, n), is non-increasing in
    // rank, and is exactly 0.0 out of range (the former panic path).
    use lamp::data::Zipf;
    forall(
        Config::default().cases(200),
        pair(Gen::usize_range(1, 64), Gen::f32_range(0.2, 2.5)),
        |&(n, s)| {
            let zipf = Zipf::new(n, s as f64);
            let total: f64 = (0..n).map(|k| zipf.pmf(k)).sum();
            let sorted = (1..n).all(|k| zipf.pmf(k) <= zipf.pmf(k - 1) + 1e-12);
            let oob = zipf.pmf(n) == 0.0 && zipf.pmf(n + 17) == 0.0;
            (total - 1.0).abs() < 1e-9 && sorted && oob
        },
    );
}
