//! Chaos suite for the fault-tolerant serving plane (PR 6).
//!
//! Global invariant, pinned over several distinct fault seeds: under a
//! deterministic `FaultPlan` every admitted request either **completes
//! bit-identical to solo decode** under its final effective policy, or
//! ends in **exactly one typed failure event** whose streamed tokens are
//! a prefix of the solo stream — never lost tokens, never duplicated
//! tokens, never double-counted `LampStats`. The suite also pins replay
//! determinism, the degradation ladder's down-and-back-up cycle,
//! deadline/cancellation semantics, and the run-budget backstop.

use lamp::coordinator::{
    DegradationLadder, Engine, FaultInjector, FaultPlan, GenerateEvent, GenerateRequest,
    KvCacheOptions, NativeEngine, PrecisionPolicy, RetryPolicy, Rule, Scheduler,
    SchedulerOptions, WeightFormat,
};
use lamp::error::Error;
use lamp::model::{Decode, ModelConfig, Weights};
use lamp::util::Rng;
use std::collections::HashMap;
use std::time::Duration;

/// Per-request fold of an event stream, asserting stream hygiene as it
/// goes: contiguous token indices, no events after a terminal, at most
/// one terminal per id.
struct Folded {
    streamed: HashMap<u64, Vec<u32>>,
    finished: HashMap<u64, lamp::coordinator::GenerateResponse>,
    failed: HashMap<u64, Error>,
}

fn fold(events: Vec<GenerateEvent>, ctx: &str) -> Folded {
    let mut f = Folded {
        streamed: HashMap::new(),
        finished: HashMap::new(),
        failed: HashMap::new(),
    };
    for ev in events {
        match ev {
            GenerateEvent::Token { id, token, index } => {
                assert!(
                    !f.finished.contains_key(&id) && !f.failed.contains_key(&id),
                    "{ctx}: id {id} streamed a token after its terminal event"
                );
                let v = f.streamed.entry(id).or_default();
                assert_eq!(
                    index,
                    v.len(),
                    "{ctx}: id {id} token indices must be contiguous"
                );
                v.push(token);
            }
            GenerateEvent::Finished(r) => {
                assert!(
                    !f.failed.contains_key(&r.id),
                    "{ctx}: id {} finished after failing",
                    r.id
                );
                let id = r.id;
                assert!(
                    f.finished.insert(id, r).is_none(),
                    "{ctx}: id {id} finished twice"
                );
            }
            GenerateEvent::Failed { id, error } => {
                assert!(
                    !f.finished.contains_key(&id),
                    "{ctx}: id {id} failed after finishing"
                );
                assert!(
                    f.failed.insert(id, error).is_none(),
                    "{ctx}: id {id} failed twice"
                );
            }
        }
    }
    f
}

#[test]
fn chaos_every_stream_is_solo_identical_or_fails_exactly_once() {
    // The tentpole invariant over five distinct fault seeds: the full
    // chaos plan (step errors, resource spikes, delays, poisoning, open
    // i/o failures) may fail individual requests, but every survivor is
    // bit-identical to solo decode, every casualty ends in exactly one
    // typed event with a solo-prefix stream, and LampStats stay
    // single-counted across however many retries/preemptions happened.
    let cfg = ModelConfig::nano();
    let mut wrng = Rng::new(7);
    let w = Weights::random(&cfg, &mut wrng).unwrap();
    let oracle = NativeEngine::new(w.clone());
    let policy = PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed);
    let mut total_injected = 0usize;

    for plan_seed in [11u64, 23, 37, 53, 71] {
        let ctx = format!("plan seed {plan_seed}");
        let mut kv = KvCacheOptions::serving(&cfg, WeightFormat::F32, 4);
        kv.sharing = false; // keep per-request causal_total comparable to solo
        let engine = NativeEngine::new(w.clone()).with_kv_cache(kv).unwrap();
        let inj = FaultInjector::new(engine, FaultPlan::chaos(plan_seed)).unwrap();
        let opts = SchedulerOptions {
            max_sessions: 4,
            prefill_chunk: 4,
            retry: RetryPolicy { max_retries: 8, backoff: Duration::ZERO, jitter: 0.0 },
            max_run_steps: Some(200_000),
            ..Default::default()
        };
        let mut sched = Scheduler::new(&inj, opts);

        let mut prompts: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut solos: HashMap<u64, Vec<u32>> = HashMap::new();
        for id in 0..8u64 {
            let prompt: Vec<u32> = (0..3 + id as usize % 4)
                .map(|j| ((id * 13 + j as u64 * 5 + 2) % 128) as u32)
                .collect();
            let max_new = 10 + id as usize % 6;
            let (solo, _) =
                oracle.generate(&prompt, max_new, &policy, Decode::Greedy, id).unwrap();
            solos.insert(id, solo);
            prompts.insert(id, prompt.clone());
            sched.admit(GenerateRequest::new(id, prompt, max_new, policy).with_seed(id));
        }

        let mut events = Vec::new();
        sched
            .run_until_idle(&mut events)
            .unwrap_or_else(|e| panic!("{ctx}: run budget tripped: {e}"));
        let f = fold(events, &ctx);

        for id in 0..8u64 {
            let solo = &solos[&id];
            let prompt_len = prompts[&id].len();
            match (f.finished.get(&id), f.failed.get(&id)) {
                (Some(r), None) => {
                    assert_eq!(&r.tokens, solo, "{ctx}: id {id} diverged from solo");
                    // No ladder configured: the effective policy is the
                    // requested one, and it is the solo-oracle key.
                    assert_eq!(r.policy, policy, "{ctx}: id {id} policy drifted");
                    let streamed =
                        f.streamed.get(&id).map(|v| v.as_slice()).unwrap_or(&[]);
                    assert_eq!(
                        streamed,
                        r.generated(),
                        "{ctx}: id {id} streamed tokens disagree with the response"
                    );
                    assert_eq!(
                        r.stats.causal_total,
                        cfg.causal_products(r.tokens.len()),
                        "{ctx}: id {id} products double-counted across retries"
                    );
                }
                (None, Some(_err)) => {
                    // A casualty keeps what it streamed — and that must be
                    // a prefix of the solo continuation.
                    let streamed =
                        f.streamed.get(&id).map(|v| v.as_slice()).unwrap_or(&[]);
                    let cont = &solo[prompt_len..];
                    assert!(
                        streamed.len() <= cont.len()
                            && streamed == &cont[..streamed.len()],
                        "{ctx}: id {id} failed stream is not a solo prefix"
                    );
                }
                _ => panic!("{ctx}: id {id} needs exactly one terminal event"),
            }
        }
        let m = sched.metrics();
        assert_eq!(m.completed, f.finished.len(), "{ctx}: completed miscounted");
        assert_eq!(m.failed, f.failed.len(), "{ctx}: failed miscounted");
        total_injected += m.faults_injected;
    }
    assert!(
        total_injected > 0,
        "five chaos seeds over ~600 fault draws must inject something"
    );
}

#[test]
fn chaos_faults_mid_speculation_stay_exact_and_leak_no_kv_blocks() {
    // PR-9 satellite: every request carries a speculative draft, so the
    // chaos plan's step errors, resource spikes and poisoning land inside
    // speculation rounds — on draft steps and on the batched verify — not
    // just on plain decode. The invariant is unchanged: every survivor
    // streams bit-identical to solo decode under the plain *target*
    // policy (speculation stays invisible under faults too), every
    // casualty keeps a solo-prefix stream plus exactly one typed failure,
    // and however many rounds were torn down mid-flight, the KV pool
    // settles back to zero used blocks — a draft checkpoint leaked by a
    // retry or preemption would show up here.
    use lamp::coordinator::{SitePolicy, SpecPolicy};
    let cfg = ModelConfig::nano();
    let mut wrng = Rng::new(29);
    let w = Weights::random(&cfg, &mut wrng).unwrap();
    let oracle = NativeEngine::new(w.clone());
    let target = PrecisionPolicy::lamp(3, 0.1, Rule::Strict);
    let drafts = [
        SpecPolicy::whole_model(SitePolicy::uniform(2), 4),
        SpecPolicy::whole_model(SitePolicy::uniform(2), 2),
        SpecPolicy::whole_model(SitePolicy::lamp(3, 0.2, Rule::Strict), 3),
    ];
    let mut total_injected = 0usize;
    let mut rounds_under_fire = 0usize;

    for plan_seed in [13u64, 41, 97] {
        let ctx = format!("plan seed {plan_seed}");
        let mut kv = KvCacheOptions::serving(&cfg, WeightFormat::F32, 3);
        kv.sharing = false; // keep per-request streams comparable to solo
        let engine = NativeEngine::new(w.clone()).with_kv_cache(kv).unwrap();
        let inj = FaultInjector::new(engine, FaultPlan::chaos(plan_seed)).unwrap();
        let opts = SchedulerOptions {
            max_sessions: 3,
            prefill_chunk: 4,
            retry: RetryPolicy { max_retries: 8, backoff: Duration::ZERO, jitter: 0.0 },
            max_run_steps: Some(200_000),
            ..Default::default()
        };
        let mut sched = Scheduler::new(&inj, opts);

        let mut prompts: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut solos: HashMap<u64, Vec<u32>> = HashMap::new();
        for id in 0..6u64 {
            let prompt: Vec<u32> = (0..3 + id as usize % 4)
                .map(|j| ((id * 11 + j as u64 * 7 + 5) % 128) as u32)
                .collect();
            let max_new = 10 + id as usize % 5;
            let (solo, _) =
                oracle.generate(&prompt, max_new, &target, Decode::Greedy, id).unwrap();
            solos.insert(id, solo);
            prompts.insert(id, prompt.clone());
            let policy = target.with_spec(Some(drafts[id as usize % drafts.len()]));
            sched.admit(GenerateRequest::new(id, prompt, max_new, policy).with_seed(id));
        }

        let mut events = Vec::new();
        sched
            .run_until_idle(&mut events)
            .unwrap_or_else(|e| panic!("{ctx}: run budget tripped: {e}"));
        let f = fold(events, &ctx);

        for id in 0..6u64 {
            let solo = &solos[&id];
            let prompt_len = prompts[&id].len();
            match (f.finished.get(&id), f.failed.get(&id)) {
                (Some(r), None) => {
                    assert_eq!(
                        &r.tokens, solo,
                        "{ctx}: id {id} speculative decode diverged from solo under faults"
                    );
                    let streamed =
                        f.streamed.get(&id).map(|v| v.as_slice()).unwrap_or(&[]);
                    assert_eq!(
                        streamed,
                        r.generated(),
                        "{ctx}: id {id} streamed tokens disagree with the response"
                    );
                    assert!(
                        r.stats.spec.accepted <= r.stats.spec.drafted,
                        "{ctx}: id {id} accepted more than it drafted"
                    );
                    assert_eq!(
                        r.stats.spec.accept_hist.iter().sum::<usize>(),
                        r.stats.spec.rounds,
                        "{ctx}: id {id} speculation rounds double-counted across retries"
                    );
                    rounds_under_fire += r.stats.spec.rounds;
                }
                (None, Some(_err)) => {
                    let streamed =
                        f.streamed.get(&id).map(|v| v.as_slice()).unwrap_or(&[]);
                    let cont = &solo[prompt_len..];
                    assert!(
                        streamed.len() <= cont.len()
                            && streamed == &cont[..streamed.len()],
                        "{ctx}: id {id} failed mid-speculation with a non-solo-prefix stream"
                    );
                }
                _ => panic!("{ctx}: id {id} needs exactly one terminal event"),
            }
        }
        assert_eq!(
            inj.kv_pool().unwrap().stats().used_blocks,
            0,
            "{ctx}: KV blocks leaked by torn-down speculation rounds"
        );
        total_injected += sched.metrics().faults_injected;
    }
    assert!(total_injected > 0, "three chaos seeds must inject faults");
    assert!(
        rounds_under_fire > 0,
        "survivors must have actually speculated under the chaos plan"
    );
}

#[test]
fn chaos_replay_with_same_seed_is_deterministic() {
    // Fault verdicts are pure functions of (plan seed, domain, session
    // seed, position, attempt) — so replaying the same workload against
    // the same plan seed yields identical per-request event streams,
    // token for token and error for error.
    let cfg = ModelConfig::nano();
    let mut wrng = Rng::new(3);
    let w = Weights::random(&cfg, &mut wrng).unwrap();

    let run = |w: &Weights| -> (Folded, usize) {
        let engine = NativeEngine::new(w.clone());
        let inj = FaultInjector::new(engine, FaultPlan::chaos(0xD5EED)).unwrap();
        let opts = SchedulerOptions {
            max_sessions: 3,
            prefill_chunk: 4,
            retry: RetryPolicy { max_retries: 8, backoff: Duration::ZERO, jitter: 0.0 },
            max_run_steps: Some(200_000),
            ..Default::default()
        };
        let mut sched = Scheduler::new(&inj, opts);
        for id in 0..6u64 {
            let prompt: Vec<u32> =
                (0..4 + id as usize % 3).map(|j| ((id * 17 + j as u64 * 3) % 128) as u32).collect();
            let decode = if id % 2 == 0 {
                Decode::Greedy
            } else {
                Decode::TopK { k: 4, temperature: 0.8 }
            };
            let policy = PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed);
            sched.admit(
                GenerateRequest::new(id, prompt, 9 + id as usize % 5, policy)
                    .with_seed(id)
                    .with_decode(decode),
            );
        }
        let mut events = Vec::new();
        sched.run_until_idle(&mut events).unwrap();
        let injected = sched.metrics().faults_injected;
        (fold(events, "replay"), injected)
    };

    let (a, inj_a) = run(&w);
    let (b, inj_b) = run(&w);
    assert_eq!(inj_a, inj_b, "fault injection counts must replay exactly");
    assert_eq!(a.streamed, b.streamed, "streamed tokens must replay exactly");
    assert_eq!(
        a.finished.keys().collect::<std::collections::BTreeSet<_>>(),
        b.finished.keys().collect::<std::collections::BTreeSet<_>>(),
        "the completed set must replay exactly"
    );
    for (id, ra) in &a.finished {
        let rb = &b.finished[id];
        assert_eq!(ra.tokens, rb.tokens, "id {id}: tokens must replay exactly");
        assert_eq!(
            ra.stats.causal_total, rb.stats.causal_total,
            "id {id}: stats must replay exactly"
        );
    }
    assert_eq!(
        a.failed.keys().collect::<std::collections::BTreeSet<_>>(),
        b.failed.keys().collect::<std::collections::BTreeSet<_>>(),
        "the failed set must replay exactly"
    );
    for (id, ea) in &a.failed {
        assert_eq!(
            format!("{ea:?}"),
            format!("{:?}", b.failed[id]),
            "id {id}: the typed error must replay exactly"
        );
    }
}

#[test]
fn chaos_degradation_ladder_steps_down_and_back_up() {
    // Pool pressure (preemptions on a 1.5-session pool) must step the
    // ladder down; a request admitted while degraded decodes under the
    // stepped-down policy — and is bit-identical to solo decode under
    // that *effective* policy; once the pool drains, the ladder steps
    // back up to rung 0.
    let cfg = ModelConfig::nano();
    let mut wrng = Rng::new(9);
    let w = Weights::random(&cfg, &mut wrng).unwrap();
    let oracle = NativeEngine::new(w.clone());

    let mut kv = KvCacheOptions::serving(&cfg, WeightFormat::F32, 1);
    kv.block_size = 4;
    kv.capacity_blocks = 12;
    kv.sharing = false;
    let engine = NativeEngine::new(w).with_kv_cache(kv).unwrap();

    // occupancy_low = 0 keeps the rung pinned until the pool fully
    // drains, so the fresh request below is guaranteed a degraded
    // admission; restore_after = 4 lets the post-drain steps restore.
    let ladder = DegradationLadder {
        occupancy_high: 1.0,
        occupancy_low: 0.0,
        degrade_after: 1,
        restore_after: 4,
        ..Default::default()
    };
    ladder.validate().unwrap();
    let opts = SchedulerOptions {
        max_sessions: 2,
        prefill_chunk: 4,
        ladder: Some(ladder),
        ..Default::default()
    };
    let mut sched = Scheduler::new(&engine, opts);

    let policy = PrecisionPolicy::lamp(3, 0.05, Rule::Strict);
    let mut prompts: HashMap<u64, Vec<u32>> = HashMap::new();
    for id in 0..3u64 {
        let prompt = vec![(id as u32 * 7 + 1) % 128, 5, 3, 2];
        prompts.insert(id, prompt.clone());
        sched.admit(GenerateRequest::new(id, prompt, 27, policy).with_seed(id));
    }
    let mut events = Vec::new();
    let mut guard = 0usize;
    while sched.metrics().ladder_rung == 0 {
        guard += 1;
        assert!(guard < 100_000, "pool pressure never moved the ladder");
        events.extend(sched.step());
    }
    // Admit a fresh request while degraded: its admission must step the
    // policy down, and its stream must match solo decode under the
    // effective (reported) policy.
    let fresh = vec![9, 8, 7, 6];
    prompts.insert(3, fresh.clone());
    sched.admit(GenerateRequest::new(3, fresh, 27, policy).with_seed(3));
    sched.run_until_idle(&mut events).unwrap();

    let f = fold(events, "ladder");
    assert!(f.failed.is_empty(), "degradation must not fail requests");
    assert_eq!(f.finished.len(), 4);
    for (id, r) in &f.finished {
        let (solo, _) = oracle
            .generate(&prompts[id], 27, &r.policy, Decode::Greedy, *id)
            .unwrap();
        assert_eq!(
            r.tokens, solo,
            "id {id}: stream must match solo decode under the effective policy"
        );
    }
    let degraded = &f.finished[&3];
    assert_ne!(
        degraded.policy, policy,
        "the request admitted under pressure must carry a stepped-down policy"
    );

    let m = sched.metrics();
    assert!(m.preemptions > 0, "the tiny pool must preempt");
    assert!(m.degrade_transitions >= 1, "pressure must step the ladder down");
    assert!(m.degraded_admissions >= 1, "the fresh request must admit degraded");

    // Drained pool: idle steps are all-clear, so the ladder restores one
    // rung per `restore_after` steps until it is back at 0.
    for _ in 0..32 {
        assert!(sched.step().is_empty(), "idle steps must emit nothing");
    }
    let m = sched.metrics();
    assert!(m.restore_transitions >= 1, "a clear pool must step the ladder up");
    assert_eq!(m.ladder_rung, 0, "the ladder must fully restore once clear");
    assert_eq!(m.ladder_rung_name, "none");
}

#[test]
fn chaos_deadlines_and_cancellation_fail_exactly_once_typed() {
    let cfg = ModelConfig::nano();
    let mut wrng = Rng::new(15);
    let w = Weights::random(&cfg, &mut wrng).unwrap();
    let engine = NativeEngine::new(w);
    let policy = PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed);
    let mut sched = Scheduler::new(
        &engine,
        SchedulerOptions { max_sessions: 1, prefill_chunk: 4, ..Default::default() },
    );

    // (a) A zero TTFT deadline expires while queued: one typed timeout,
    // no tokens, no session ever opened.
    sched.admit(
        GenerateRequest::new(0, vec![1, 2, 3], 8, policy)
            .with_seed(0)
            .with_ttft_deadline(Duration::ZERO),
    );
    let mut events = Vec::new();
    sched.run_until_idle(&mut events).unwrap();
    let f = fold(events, "ttft");
    assert!(f.streamed.is_empty(), "an expired request must stream nothing");
    assert!(f.failed.get(&0).is_some_and(Error::is_timeout));
    assert_eq!(f.failed.len(), 1);

    // (b) A token canceled before the run starts: one typed cancellation.
    let mut req = GenerateRequest::new(1, vec![1, 2, 3], 8, policy).with_seed(1);
    let token = req.cancel_token();
    token.cancel();
    sched.admit(req);
    let mut events = Vec::new();
    sched.run_until_idle(&mut events).unwrap();
    let f = fold(events, "queued-cancel");
    assert!(f.failed.get(&1).is_some_and(Error::is_canceled));
    assert!(f.streamed.is_empty());

    // (c) Cancellation mid-stream keeps every token already streamed —
    // and those tokens are a prefix of the solo stream.
    let mut req = GenerateRequest::new(2, vec![1, 2, 3], 24, policy).with_seed(2);
    let token = req.cancel_token();
    sched.admit(req);
    let mut streamed: Vec<u32> = Vec::new();
    let mut terminal: Option<Error> = None;
    let mut guard = 0usize;
    while !sched.is_idle() {
        guard += 1;
        assert!(guard < 100_000, "cancellation never took effect");
        for ev in sched.step() {
            match ev {
                GenerateEvent::Token { token: t, .. } => {
                    streamed.push(t);
                    if streamed.len() == 3 {
                        token.cancel();
                    }
                }
                GenerateEvent::Failed { error, .. } => {
                    assert!(terminal.is_none(), "exactly one terminal event");
                    terminal = Some(error);
                }
                GenerateEvent::Finished(_) => panic!("a canceled request must not finish"),
            }
        }
    }
    let err = terminal.expect("the canceled request must fail");
    assert!(err.is_canceled(), "cancellation must surface as Error::Canceled");
    assert_eq!(streamed.len(), 3, "cancellation keeps exactly the streamed prefix");
    let (solo, _) = engine.generate(&[1, 2, 3], 24, &policy, Decode::Greedy, 2).unwrap();
    assert_eq!(&streamed[..], &solo[3..6], "kept tokens must be a solo prefix");

    let m = sched.metrics();
    assert_eq!(m.timeouts, 1);
    assert_eq!(m.canceled, 2);
    assert_eq!(m.failed, 3);
    assert_eq!(m.completed, 0);
}

#[test]
fn chaos_run_budget_fails_wedged_queues_with_typed_timeouts() {
    // A session opened outside the scheduler wedges the pool (7 of 8
    // blocks held), permanently gating admission. The step budget must
    // convert the would-be infinite spin into one typed timeout event
    // per request plus a typed `Err` from the drive itself.
    let cfg = ModelConfig::nano();
    let mut wrng = Rng::new(21);
    let w = Weights::random(&cfg, &mut wrng).unwrap();
    let mut kv = KvCacheOptions::serving(&cfg, WeightFormat::F32, 1);
    kv.block_size = 4;
    kv.capacity_blocks = 8;
    kv.sharing = false;
    let engine = NativeEngine::new(w).with_kv_cache(kv).unwrap();
    let policy = PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed);

    let mut wedge = engine.decode_session(&policy, 99).unwrap();
    for t in 0..25u32 {
        wedge.decode_step(t % 128).unwrap();
    }

    let opts = SchedulerOptions {
        max_sessions: 2,
        prefill_chunk: 4,
        max_run_steps: Some(64),
        ..Default::default()
    };
    let mut sched = Scheduler::new(&engine, opts);
    for id in 0..2u64 {
        // 8 prompt tokens need 2 blocks; only 1 is free: gated forever.
        let req = GenerateRequest::new(id, vec![1, 2, 3, 4, 5, 6, 7, 8], 8, policy);
        sched.admit(req.with_seed(id));
    }
    let mut events = Vec::new();
    let err = sched.run_until_idle(&mut events).unwrap_err();
    assert!(err.is_timeout(), "a tripped step budget must be Error::Timeout");
    let mut ids: Vec<u64> = events
        .iter()
        .map(|e| match e {
            GenerateEvent::Failed { id, error } => {
                assert!(error.is_timeout(), "aborted requests must fail typed");
                *id
            }
            _ => panic!("a gated queue must emit nothing but timeout failures"),
        })
        .collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1], "exactly one timeout event per request");
    let m = sched.metrics();
    assert_eq!(m.timeouts, 2);
    assert_eq!(m.failed, 2);
    assert_eq!(m.completed, 0);

    // The wall-clock budget trips the same way.
    let mut wall = Scheduler::new(
        &engine,
        SchedulerOptions {
            max_sessions: 1,
            max_run_wall: Some(Duration::from_millis(2)),
            ..Default::default()
        },
    );
    wall.admit(GenerateRequest::new(7, vec![1, 2, 3, 4, 5, 6, 7, 8], 8, policy).with_seed(7));
    let mut events = Vec::new();
    let err = wall.run_until_idle(&mut events).unwrap_err();
    assert!(err.is_timeout());
    assert!(matches!(
        events.as_slice(),
        [GenerateEvent::Failed { id: 7, error }] if error.is_timeout()
    ));
    drop(wedge);
}
