//! Differential oracle for the continuous-batching decode scheduler: under
//! randomized arrival schedules — staggered admits, mixed prompt lengths,
//! mixed precision policies, mixed sampling params, varying slot counts and
//! prefill chunking, with and without the thread pool — every request's
//! token stream must be **bit-identical** to running that request alone
//! through `NativeEngine::generate` with the same seed, and its `LampStats`
//! accounting must match the solo session exactly.

use lamp::coordinator::{
    Engine, GenerateEvent, GenerateRequest, NativeEngine, PrecisionPolicy, Rule, Scheduler,
    SchedulerOptions,
};
use lamp::model::{Decode, ModelConfig, Weights};
use lamp::util::{Rng, ThreadPool};
use std::collections::HashMap;
use std::sync::Arc;

fn nano_engine(seed: u64) -> NativeEngine {
    let mut rng = Rng::new(seed);
    NativeEngine::new(Weights::random(&ModelConfig::nano(), &mut rng).unwrap())
}

fn policy_menu() -> Vec<PrecisionPolicy> {
    vec![
        PrecisionPolicy::reference(),
        PrecisionPolicy::uniform(3),
        PrecisionPolicy::lamp(3, 0.02, Rule::Strict),
        PrecisionPolicy::lamp(3, 0.1, Rule::Relaxed),
        PrecisionPolicy::lamp(3, 0.08, Rule::RelaxedLengthNorm),
        PrecisionPolicy::lamp(3, 0.05, Rule::Random),
    ]
}

fn random_request(id: u64, vocab: usize, rng: &mut Rng) -> GenerateRequest {
    let menu = policy_menu();
    let prompt_len = rng.range(1, 9);
    let prompt: Vec<u32> = (0..prompt_len).map(|_| rng.below(vocab as u64) as u32).collect();
    let max_new = rng.range(0, 13);
    let policy = menu[rng.range(0, menu.len())];
    let decode = if rng.below(2) == 0 {
        Decode::Greedy
    } else {
        Decode::TopK { k: rng.range(1, 9), temperature: 0.6 + rng.f32() * 1.2 }
    };
    GenerateRequest::new(id, prompt, max_new, policy)
        .with_decode(decode)
        .with_seed(rng.next_u64() >> 1)
}

/// Drive a scheduler over a randomized arrival schedule: between steps,
/// admit a random number of the remaining requests. Panics on any Failed
/// event; returns (responses by id, streamed tokens by id).
#[allow(clippy::type_complexity)]
fn run_schedule(
    engine: &NativeEngine,
    mut remaining: Vec<GenerateRequest>,
    opts: SchedulerOptions,
    rng: &mut Rng,
) -> (HashMap<u64, lamp::coordinator::GenerateResponse>, HashMap<u64, Vec<u32>>) {
    let mut sched = Scheduler::new(engine, opts);
    let mut responses = HashMap::new();
    let mut streams: HashMap<u64, Vec<u32>> = HashMap::new();
    // Admit at least one up front, the rest in random bursts between steps.
    let first = remaining.remove(0);
    sched.admit(first);
    loop {
        if !remaining.is_empty() {
            // Random burst, but never let the scheduler sit idle while
            // requests are still waiting to arrive.
            let mut burst = rng.range(0, remaining.len().min(3) + 1);
            if burst == 0 && sched.is_idle() {
                burst = 1;
            }
            for _ in 0..burst {
                sched.admit(remaining.remove(0));
            }
        }
        let events = sched.step();
        for ev in events {
            match ev {
                GenerateEvent::Token { id, token, index } => {
                    let s = streams.entry(id).or_default();
                    assert_eq!(index, s.len(), "out-of-order stream for {id}");
                    s.push(token);
                }
                GenerateEvent::Finished(r) => {
                    assert!(responses.insert(r.id, r).is_none(), "duplicate response");
                }
                GenerateEvent::Failed { id, error } => {
                    panic!("request {id} failed unexpectedly: {error}")
                }
            }
        }
        if remaining.is_empty() && sched.is_idle() {
            break;
        }
    }
    (responses, streams)
}

#[test]
fn randomized_schedules_match_solo_generate() {
    let engine = nano_engine(1);
    let vocab = engine.config().vocab;
    let pool = Arc::new(ThreadPool::new(3));
    let mut rng = Rng::new(0xD1FF);
    for trial in 0..10u64 {
        let n = rng.range(3, 9);
        let reqs: Vec<GenerateRequest> =
            (0..n).map(|i| random_request(trial * 100 + i as u64, vocab, &mut rng)).collect();

        // Solo oracle: each request alone on the engine, same seed.
        let mut solo_tokens = HashMap::new();
        let mut solo_rates = HashMap::new();
        for r in &reqs {
            let (toks, rate) = engine
                .generate(&r.prompt, r.max_new_tokens, &r.policy, r.decode, r.seed)
                .unwrap();
            solo_tokens.insert(r.id, toks);
            solo_rates.insert(r.id, rate);
        }

        let opts = SchedulerOptions {
            max_sessions: rng.range(1, 5),
            prefill_chunk: rng.range(1, 5),
            pool: if rng.below(2) == 0 { Some(pool.clone()) } else { None },
            ..Default::default()
        };
        let (responses, streams) = run_schedule(&engine, reqs.clone(), opts, &mut rng);
        assert_eq!(responses.len(), n, "trial {trial}: lost responses");

        for r in &reqs {
            let resp = &responses[&r.id];
            let solo = &solo_tokens[&r.id];
            assert_eq!(
                &resp.tokens, solo,
                "trial {trial} id {}: scheduler diverged from solo decode \
                 (policy {}, prompt {} tokens, {} new)",
                r.id,
                r.policy.label(),
                r.prompt.len(),
                r.max_new_tokens
            );
            // Streamed tokens equal the response suffix.
            let streamed = streams.get(&r.id).cloned().unwrap_or_default();
            assert_eq!(resp.generated(), &streamed[..], "stream mismatch for {}", r.id);
            // Stats accounting is consistent and identical to solo decode.
            assert_eq!(
                resp.stats.rate(),
                solo_rates[&r.id],
                "trial {trial} id {}: recompute rate diverged",
                r.id
            );
            assert_eq!(
                resp.stats.recomputed,
                resp.stats.per_layer.iter().sum::<usize>(),
                "per-layer counters must sum to the total"
            );
            // Each decoded position is counted once. Mirroring the solo
            // loop, every sampled token is also fed — except when the
            // context fills (the solo loop's early break), and degenerate
            // requests never open a session.
            let fed = if resp.generated().is_empty() {
                0
            } else if resp.tokens.len() >= engine.config().seq {
                resp.tokens.len() - 1
            } else {
                resp.tokens.len()
            };
            assert_eq!(
                resp.stats.causal_total,
                engine.config().causal_products(fed),
                "trial {trial} id {}: causal product accounting",
                r.id
            );
        }
    }
}

#[test]
fn speculative_randomized_schedules_match_solo() {
    // PR-9 scheduler pin: speculative and plain requests mixed in one
    // randomized arrival schedule all stream bit-identically to solo
    // decode under the *plain target* policy — speculation is invisible in
    // the output, visible only in the acceptance accounting.
    use lamp::coordinator::{SitePolicy, SpecPolicy};
    let engine = nano_engine(3);
    let vocab = engine.config().vocab;
    let pool = Arc::new(ThreadPool::new(3));
    let mut rng = Rng::new(0x5BEC);
    let target = PrecisionPolicy::lamp(3, 0.1, Rule::Strict);
    let drafts = [
        SpecPolicy::whole_model(SitePolicy::uniform(2), 2),
        SpecPolicy::whole_model(SitePolicy::uniform(2), 4),
        SpecPolicy::whole_model(SitePolicy::uniform(3), 3),
        SpecPolicy::whole_model(SitePolicy::lamp(3, 0.2, Rule::Strict), 5),
    ];
    for trial in 0..6u64 {
        let n = rng.range(3, 7);
        let reqs: Vec<GenerateRequest> = (0..n)
            .map(|i| {
                let prompt_len = rng.range(1, 9);
                let prompt: Vec<u32> =
                    (0..prompt_len).map(|_| rng.below(vocab as u64) as u32).collect();
                let max_new = rng.range(0, 15);
                let policy = if rng.below(4) == 0 {
                    target
                } else {
                    target.with_spec(Some(drafts[rng.range(0, drafts.len())]))
                };
                let decode = if rng.below(2) == 0 {
                    Decode::Greedy
                } else {
                    Decode::TopK { k: rng.range(1, 9), temperature: 0.6 + rng.f32() * 1.2 }
                };
                GenerateRequest::new(trial * 100 + i as u64, prompt, max_new, policy)
                    .with_decode(decode)
                    .with_seed(rng.next_u64() >> 1)
            })
            .collect();

        // Solo oracle under the plain target policy, same seed: the spec
        // requests must reproduce it exactly.
        let mut solo_tokens = HashMap::new();
        for r in &reqs {
            let (toks, _) = engine
                .generate(&r.prompt, r.max_new_tokens, &target, r.decode, r.seed)
                .unwrap();
            solo_tokens.insert(r.id, toks);
        }

        let opts = SchedulerOptions {
            max_sessions: rng.range(1, 4),
            prefill_chunk: rng.range(1, 5),
            pool: if rng.below(2) == 0 { Some(pool.clone()) } else { None },
            ..Default::default()
        };
        let (responses, streams) = run_schedule(&engine, reqs.clone(), opts, &mut rng);
        assert_eq!(responses.len(), n, "trial {trial}: lost responses");
        for r in &reqs {
            let resp = &responses[&r.id];
            assert_eq!(
                &resp.tokens, &solo_tokens[&r.id],
                "trial {trial} id {}: speculative scheduling changed the stream \
                 (spec {:?}, prompt {} tokens, {} new)",
                r.id,
                r.policy.spec.map(|s| s.k),
                r.prompt.len(),
                r.max_new_tokens
            );
            let streamed = streams.get(&r.id).cloned().unwrap_or_default();
            assert_eq!(resp.generated(), &streamed[..], "stream mismatch for {}", r.id);
            if r.policy.spec.is_some() && resp.generated().len() >= 3 {
                // max_new >= 3 always leaves look-ahead room after the
                // seed token, so at least one round must have run.
                assert!(
                    resp.stats.spec.rounds > 0,
                    "trial {trial} id {}: spec request never speculated",
                    r.id
                );
                assert!(resp.stats.spec.accepted <= resp.stats.spec.drafted);
                assert_eq!(
                    resp.stats.spec.accept_hist.iter().sum::<usize>(),
                    resp.stats.spec.rounds,
                    "every round lands in one histogram bucket"
                );
            } else if r.policy.spec.is_none() {
                assert_eq!(
                    resp.stats.spec.rounds, 0,
                    "plain request accrued speculative rounds"
                );
            }
        }
    }
}

#[test]
fn arrival_order_cannot_change_any_stream() {
    // The strongest interleaving property: the same request set served
    // under different schedules, slot counts, and pool configurations
    // produces byte-identical responses.
    let engine = nano_engine(2);
    let vocab = engine.config().vocab;
    let mut rng = Rng::new(77);
    let reqs: Vec<GenerateRequest> =
        (0..6).map(|i| random_request(i, vocab, &mut rng)).collect();

    let mut reference: Option<Vec<(u64, Vec<u32>, usize)>> = None;
    for (max_sessions, prefill_chunk, threads) in
        [(1, 1, 0), (2, 3, 0), (6, 2, 2), (3, 4, 3)]
    {
        let opts = SchedulerOptions {
            max_sessions,
            prefill_chunk,
            pool: if threads == 0 { None } else { Some(Arc::new(ThreadPool::new(threads))) },
            ..Default::default()
        };
        let mut order = reqs.clone();
        // A different arrival permutation each round.
        for i in (1..order.len()).rev() {
            let j = rng.below((i + 1) as u64) as usize;
            order.swap(i, j);
        }
        let (responses, _) = run_schedule(&engine, order, opts, &mut rng);
        let mut got: Vec<(u64, Vec<u32>, usize)> = responses
            .into_values()
            .map(|r| (r.id, r.tokens, r.stats.recomputed))
            .collect();
        got.sort_by_key(|(id, _, _)| *id);
        match &reference {
            None => reference = Some(got),
            Some(want) => assert_eq!(want, &got, "schedule changed an output"),
        }
    }
}

#[test]
fn prefix_sharing_streams_bit_identical_to_unshared() {
    // PR-5 satellite pin: sessions that adopt a shared prompt prefix from
    // the pool's prefix index stream exactly the tokens an unshared (or
    // solo) run produces — for deterministic and Random rules — and the
    // pool actually records adoptions.
    use lamp::coordinator::{KvCacheOptions, WeightFormat};
    use lamp::model::{ModelConfig as MC, Weights as W};
    let cfg = MC::nano();
    let mut wrng = Rng::new(91);
    let w = W::random(&cfg, &mut wrng).unwrap();
    let solo_engine = NativeEngine::new(w.clone());

    let mut opts = KvCacheOptions::serving(&cfg, WeightFormat::F32, 8);
    opts.block_size = 4; // small blocks so short prompts publish
    let shared_engine = NativeEngine::new(w).with_kv_cache(opts).unwrap();

    // Four requests: a common 9-token prompt prefix (two full blocks),
    // distinct suffixes, same policy AND same seed — the sharing key.
    let policy = PrecisionPolicy::lamp(3, 0.05, Rule::Random);
    let prefix: Vec<u32> = (0..9).map(|i| (i * 5 + 2) % 128).collect();
    let mut reqs = Vec::new();
    for id in 0..4u64 {
        let mut prompt = prefix.clone();
        prompt.push((id as u32 * 17 + 1) % 128);
        reqs.push(GenerateRequest::new(id, prompt, 6, policy).with_seed(7));
    }

    // Solo oracle (private contiguous-equivalent caches, no sharing).
    let mut solos = Vec::new();
    for r in &reqs {
        solos.push(
            solo_engine
                .generate(&r.prompt, r.max_new_tokens, &r.policy, r.decode, r.seed)
                .unwrap()
                .0,
        );
    }

    // Staggered admission on the sharing engine: the first request
    // publishes the prefix blocks, the later ones adopt them.
    let mut sched = Scheduler::new(
        &shared_engine,
        SchedulerOptions { max_sessions: 2, prefill_chunk: 3, pool: None, ..Default::default() },
    );
    let mut responses = Vec::new();
    let mut queue: Vec<GenerateRequest> = reqs.clone();
    sched.admit(queue.remove(0));
    let mut guard = 0usize;
    loop {
        guard += 1;
        assert!(guard < 100_000, "scheduler made no progress");
        for ev in sched.step() {
            if let GenerateEvent::Finished(r) = ev {
                // Admit the next request only after one fully retires, so
                // its blocks are published before the adopter arrives.
                if let Some(next) = (!queue.is_empty()).then(|| queue.remove(0)) {
                    sched.admit(next);
                }
                responses.push(r);
            }
        }
        if queue.is_empty() && sched.is_idle() {
            break;
        }
    }
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 4);
    for (r, solo) in responses.iter().zip(&solos) {
        assert_eq!(
            &r.tokens, solo,
            "id {}: prefix sharing changed the stream",
            r.id
        );
    }
    let m = sched.metrics();
    assert!(
        m.prefix_share_hits >= 1,
        "later sessions must adopt the published prefix (hits={})",
        m.prefix_share_hits
    );
    assert!(m.prefix_share_rate > 0.0);
    assert_eq!(m.kv_format, "f32");
    // Adopted sessions skip the shared prefix's products: total evaluated
    // products across the shared run are strictly fewer than 4 solo runs.
    let solo_products: usize = solos
        .iter()
        .map(|toks| shared_engine.config().causal_products(toks.len()))
        .sum();
    let shared_products: usize =
        responses.iter().map(|r| r.stats.causal_total).sum();
    assert!(
        shared_products < solo_products,
        "sharing saved nothing: {shared_products} vs {solo_products}"
    );
}

#[test]
fn preemption_and_fault_injection_compose_bit_identically() {
    // PR-6 tentpole pin: a tiny KV pool (forcing preemption) combined with
    // injected transient step faults and delays (forcing in-place retries)
    // must leave every stream bit-identical to solo decode and every
    // request's LampStats single-counted — the retry path re-feeds, never
    // re-samples, and preempted sessions re-count their prefix from
    // scratch exactly as without injection.
    use lamp::coordinator::{
        FaultInjector, FaultPlan, KvCacheOptions, RetryPolicy, WeightFormat,
    };
    use std::time::Duration;

    let cfg = ModelConfig::nano();
    let mut wrng = Rng::new(47);
    let w = Weights::random(&cfg, &mut wrng).unwrap();
    let oracle = NativeEngine::new(w.clone());

    let mut kv_opts = KvCacheOptions::serving(&cfg, WeightFormat::F32, 1);
    kv_opts.block_size = 4;
    kv_opts.capacity_blocks = 12; // ~1.5 full-context sessions
    kv_opts.sharing = false; // keep per-request stats comparable to solo
    let engine = NativeEngine::new(w).with_kv_cache(kv_opts).unwrap();
    // Transient faults + delays only: every injected failure is retryable,
    // so with a generous retry budget no request may fail.
    let plan = FaultPlan::quiet(0xC4A05)
        .with_step_errors(0.3)
        .with_delay(0.1, Duration::from_micros(50));
    let inj = FaultInjector::new(engine, plan).unwrap();

    let policy = PrecisionPolicy::lamp(3, 0.05, Rule::Strict);
    let opts = SchedulerOptions {
        max_sessions: 2,
        prefill_chunk: 4,
        retry: RetryPolicy { max_retries: 30, backoff: Duration::ZERO, jitter: 0.0 },
        ..Default::default()
    };
    let mut sched = Scheduler::new(&inj, opts);
    let mut solos = Vec::new();
    for id in 0..3u64 {
        let prompt = vec![(id as u32 * 11 + 3) % 128, 7, 9, 2];
        solos.push(oracle.generate(&prompt, 27, &policy, Decode::Greedy, id).unwrap());
        sched.admit(GenerateRequest::new(id, prompt, 27, policy).with_seed(id));
    }
    let mut responses = sched.run_to_completion().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 3, "a retryable-only fault plan may fail nothing");
    for (r, (toks, rate)) in responses.iter().zip(&solos) {
        assert_eq!(&r.tokens, toks, "id {}: faults/preemption changed the stream", r.id);
        assert_eq!(
            r.stats.causal_total,
            cfg.causal_products(r.tokens.len()),
            "id {}: products double-counted across retries/preemption",
            r.id
        );
        assert_eq!(r.stats.rate(), *rate, "id {}: recompute rate diverged", r.id);
    }
    let m = sched.metrics();
    assert!(m.preemptions > 0, "the 1.5-session pool must force preemption");
    assert!(m.retries > 0, "a 30% step-error rate must force retries");
    assert!(m.faults_injected > 0, "injector counters must surface in metrics");
    assert_eq!(m.failed, 0);
}
