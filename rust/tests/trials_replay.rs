//! Golden determinism suite for the trials subsystem (DESIGN.md §Trials).
//!
//! The contract under test: same manifest + seed ⇒ byte-identical canonical
//! artifact — across reruns, across thread-pool sizes, and under injected
//! faults. Plus the bench-diff gate end-to-end: the committed CI baselines
//! must parse and self-diff clean, and an injected throughput regression
//! must fail the gate.

use lamp::benchkit::{bench_diff, DiffOptions};
use lamp::trials::{builtin, first_divergence, run, TrialManifest, BUILTIN};

fn run_canonical(manifest: &TrialManifest) -> String {
    run(manifest).expect("trial run").canonical
}

#[test]
fn every_bundled_manifest_replays_byte_identically() {
    for (name, text) in BUILTIN {
        let manifest = TrialManifest::parse(text).expect(name);
        let a = run_canonical(&manifest);
        let b = run_canonical(&manifest);
        if let Some(d) = first_divergence(&a, &b) {
            panic!("{name}: reruns diverge: {d}");
        }
        assert!(a.starts_with(&format!("trial = {name}\n")), "{name}: header");
        if manifest.figure.is_some() {
            // Figure trials pin per-mu blocks with bit-exact floats.
            assert!(a.contains("\n[mu "), "{name}: per-mu blocks");
            assert!(a.contains("bits="), "{name}: floats must be bit-pinned");
        } else {
            assert!(a.contains("\n[request 0]\n"), "{name}: per-request blocks");
        }
        assert!(a.ends_with('\n'), "{name}: artifact must be newline-terminated");
    }
}

#[test]
fn replay_is_invariant_across_thread_pool_sizes() {
    // A kv-less manifest: prefix-share adoption is the one per-request stats
    // source that may depend on pool shape, so the cross-worker golden runs
    // the bursty trace (no [kv] section) and compares against workers = 0.
    let mut manifest = TrialManifest::parse(builtin("bursty").expect("bundled")).unwrap();
    assert!(manifest.kv_format.is_none(), "cross-pool golden needs a kv-less trial");
    let base = run_canonical(&manifest);
    for workers in [1usize, 2, 4] {
        manifest.workers = workers;
        let out = run_canonical(&manifest);
        if let Some(d) = first_divergence(&base, &out) {
            panic!("workers={workers} diverges from workers=0: {d}");
        }
    }
}

#[test]
fn chaos_outcomes_replay_byte_identically() {
    // Fault verdicts are pure seeded hashes keyed on (plan seed, session
    // seed, position, attempt) — outcomes, including failures, must replay.
    let manifest = TrialManifest::parse(builtin("chaos-replay").expect("bundled")).unwrap();
    let a = run_canonical(&manifest);
    let b = run_canonical(&manifest);
    assert_eq!(a, b, "chaos verdicts must be schedule-independent");
    assert!(a.contains("faults = chaos\n"), "chaos plan recorded in the artifact");
}

#[test]
fn committed_baselines_parse_and_self_diff_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    for rel in ["baselines/BENCH_PR2.smoke.json", "baselines/BENCH_PR3.smoke.json"] {
        let text = std::fs::read_to_string(root.join(rel))
            .unwrap_or_else(|e| panic!("{rel}: {e}"));
        let report = bench_diff(&text, &text, &DiffOptions::default())
            .unwrap_or_else(|e| panic!("{rel}: {e}"));
        assert!(report.passed(), "{rel} self-diff failed:\n{}", report.render());
    }
}

#[test]
fn bench_gate_catches_injected_regression_end_to_end() {
    let baseline = "{\n  \"serving_load\": {\"continuous_tok_s\": 1000.0, \"requests\": 8},\n}\n";
    let current = "{\n  \"serving_load\": {\"continuous_tok_s\": 10.0, \"requests\": 8},\n}\n";
    let report = bench_diff(baseline, current, &DiffOptions::default()).unwrap();
    assert!(!report.passed(), "99% throughput drop must fail the gate");
    let report = bench_diff(baseline, baseline, &DiffOptions::default()).unwrap();
    assert!(report.passed(), "identical records must pass");
}
