//! Mixed-precision weight storage — the PR-4 acceptance suite.
//!
//! * Quantization properties: `quantize_to` is idempotent (dequant is
//!   exact, so requantization is the identity) and its error is bounded
//!   by one ulp at the target precision (μ mantissa bits for PS storage,
//!   7 for bf16).
//! * Format compatibility: f32-only tensor files stay byte-identical v1
//!   (backward-compat read), quantized files round-trip through v2.
//! * Fused-dequant kernels: running the model on quantized storage is
//!   bitwise identical to dequantizing the weights into f32 storage
//!   first — through the batched forward, the KV-cache decode path, and
//!   the serving engine.
//! * Control plane: storage-pinned policies are gated per engine, the
//!   scheduler serves generation on quantized engines bit-identically to
//!   solo decode, and stats attribute the storage format.

use lamp::coordinator::{
    Engine, GenerateRequest, NativeEngine, PrecisionPolicy, Rule, Server, SitePolicy,
    WeightPrecision,
};
use lamp::linalg::{Matrix, WeightFormat, WeightTensor};
use lamp::model::{generate, Decode, ModelConfig, PrecisionPlan, Weights};
use lamp::softfloat::round::ulp_at;
use lamp::tensorio::TensorFile;
use lamp::util::Rng;
use std::time::Duration;

fn nano_weights(seed: u64) -> Weights {
    let mut rng = Rng::new(seed);
    Weights::random(&ModelConfig::nano(), &mut rng).unwrap()
}

#[test]
fn quantization_error_bounded_by_one_ulp_and_idempotent() {
    let mut rng = Rng::new(1);
    for _ in 0..20 {
        let m = Matrix::randn(6, 17, 3.0, &mut rng);
        for (fmt, mu) in [
            (WeightFormat::Bf16, 7u32),
            (WeightFormat::PsRounded { mu: 4 }, 4),
            (WeightFormat::PsRounded { mu: 11 }, 11),
        ] {
            let q = WeightTensor::from_matrix(&m, fmt).unwrap();
            // Idempotent: dequantizing and requantizing changes nothing.
            assert_eq!(q.quantize_to(fmt).unwrap(), q, "{fmt:?} not idempotent");
            let deq = q.to_matrix();
            for r in 0..m.rows() {
                for c in 0..m.cols() {
                    let x = m.get(r, c);
                    let err = (deq.get(r, c) - x).abs();
                    if x != 0.0 {
                        assert!(
                            err <= ulp_at(x, mu),
                            "{fmt:?}: err {err} > 1 ulp at ({r},{c}), x={x}"
                        );
                    } else {
                        assert_eq!(err, 0.0);
                    }
                }
            }
        }
    }
}

#[test]
fn f32_weight_files_stay_v1_and_quantized_files_roundtrip_v2() {
    let w = nano_weights(2);
    let f32_file = w.to_tensor_file().unwrap();
    let bytes = f32_file.to_bytes();
    // Backward compat: the f32-storage writer's output is a v1 file that
    // the (v1-era) reader contract accepts and reproduces exactly.
    assert_eq!(&bytes[8..12], &1u32.to_le_bytes(), "f32 weights must stay v1");
    let back = Weights::from_tensor_file(&TensorFile::from_bytes(&bytes).unwrap(), &w.config)
        .unwrap();
    assert_eq!(back.wte, w.wte);
    assert_eq!(back.weight_format(), WeightFormat::F32);
    // Quantized storage round-trips through v2 preserving format + bits.
    for fmt in [WeightFormat::Bf16, WeightFormat::PsRounded { mu: 6 }] {
        let q = w.quantize_to(fmt).unwrap();
        let bytes = q.to_tensor_file().unwrap().to_bytes();
        assert_eq!(&bytes[8..12], &2u32.to_le_bytes());
        let back = Weights::from_tensor_file(
            &TensorFile::from_bytes(&bytes).unwrap(),
            &w.config,
        )
        .unwrap();
        assert_eq!(back.weight_format(), fmt);
        assert_eq!(back.wte, q.wte);
        assert_eq!(back.blocks[1].w_out, q.blocks[1].w_out);
    }
}

#[test]
fn engine_on_quantized_storage_matches_dequantized_engine_bitwise() {
    // The fused-dequant contract at engine level: an engine holding bf16
    // (or PS) storage produces exactly the outputs of an engine holding
    // the dequantized f32 copies of the same values — for batched infer
    // and for generation.
    let w = nano_weights(3);
    let tokens = vec![vec![1u32; 10], vec![9u32; 10]];
    for fmt in [WeightFormat::Bf16, WeightFormat::PsRounded { mu: 7 }] {
        let q = w.quantize_to(fmt).unwrap();
        let deq = q.quantize_to(WeightFormat::F32).unwrap();
        let qe = NativeEngine::new(q.clone());
        let fe = NativeEngine::new(deq);
        for policy in [
            PrecisionPolicy::reference(),
            PrecisionPolicy::lamp(3, 0.05, Rule::Strict),
            PrecisionPolicy::tier("balanced-whole").unwrap(),
        ] {
            let a = qe.infer(&tokens, &policy, 1).unwrap();
            let b = fe.infer(&tokens, &policy, 1).unwrap();
            assert_eq!(a.logits, b.logits, "{fmt:?} infer under {}", policy.label());
            assert_eq!(a.stats.recomputed, b.stats.recomputed);
        }
        let (ta, _) = generate(&q, &[1, 2, 3], 8, PrecisionPlan::reference(), Decode::Greedy, 5)
            .unwrap();
        let (tb, _) = generate(
            &q.quantize_to(WeightFormat::F32).unwrap(),
            &[1, 2, 3],
            8,
            PrecisionPlan::reference(),
            Decode::Greedy,
            5,
        )
        .unwrap();
        assert_eq!(ta, tb, "{fmt:?} generation token stream");
    }
}

#[test]
fn quantized_storage_perturbs_logits_but_bounded() {
    // Storage error is real and bounded: bf16 logits differ from f32 ones,
    // and the deviation shrinks as storage precision grows (ps4 ⊃ ps8).
    let w = nano_weights(4);
    let tokens: Vec<u32> = (0..16).map(|i| (i * 7 + 1) % 128).collect();
    let reference = lamp::model::forward(&w, &tokens, PrecisionPlan::reference(), 0).unwrap();
    let err = |fmt: WeightFormat| -> f32 {
        let q = w.quantize_to(fmt).unwrap();
        lamp::model::forward(&q, &tokens, PrecisionPlan::reference(), 0)
            .unwrap()
            .logits
            .max_abs_diff(&reference.logits)
            .unwrap()
    };
    let e_bf16 = err(WeightFormat::Bf16);
    let e_ps4 = err(WeightFormat::PsRounded { mu: 4 });
    let e_ps8 = err(WeightFormat::PsRounded { mu: 8 });
    assert!(e_bf16 > 0.0, "bf16 storage must perturb logits");
    assert!(e_ps4 > e_ps8, "coarser storage must hurt more: {e_ps4} vs {e_ps8}");
    assert!(e_bf16 < 1.0, "bf16 storage error implausibly large: {e_bf16}");
}

#[test]
fn scheduler_serves_generation_on_quantized_engine_bit_identically() {
    // Continuous-batching decode inherits the storage transparently: the
    // scheduler's per-request streams on a bf16 engine equal solo decode
    // on the same bf16 weights.
    let w = nano_weights(5).quantize_to(WeightFormat::Bf16).unwrap();
    let solo = NativeEngine::new(w.clone());
    let mut server =
        Server::new(Box::new(NativeEngine::new(w)), Duration::from_millis(1));
    let policy = PrecisionPolicy::lamp(3, 0.05, Rule::Strict)
        .with_mlp(SitePolicy::lamp(4, 1.0, Rule::Strict));
    server
        .submit_generate(GenerateRequest::new(1, vec![1, 2, 3], 6, policy))
        .unwrap();
    server
        .submit_generate(GenerateRequest::new(2, vec![9, 8], 4, policy))
        .unwrap();
    let events = server.serve_generation().unwrap();
    let mut finished: Vec<_> = events
        .into_iter()
        .filter_map(|e| match e {
            lamp::coordinator::GenerateEvent::Finished(r) => Some(r),
            lamp::coordinator::GenerateEvent::Failed { id, error } => {
                panic!("request {id} failed: {error}")
            }
            _ => None,
        })
        .collect();
    finished.sort_by_key(|r| r.id);
    let (s1, _) = solo.generate(&[1, 2, 3], 6, &policy, Decode::Greedy, 1).unwrap();
    let (s2, _) = solo.generate(&[9, 8], 4, &policy, Decode::Greedy, 2).unwrap();
    assert_eq!(finished[0].tokens, s1);
    assert_eq!(finished[1].tokens, s2);
    let stats = server.stats();
    assert_eq!(stats.weight_format, "bf16");
}

#[test]
fn storage_pinned_policies_gate_per_engine() {
    let w = nano_weights(6);
    let f32_engine = NativeEngine::new(w.clone());
    let bf16_engine = NativeEngine::new(w).with_weight_format(WeightFormat::Bf16).unwrap();
    assert_eq!(f32_engine.weight_format(), WeightFormat::F32);
    assert_eq!(bf16_engine.weight_format(), WeightFormat::Bf16);
    let pinned = PrecisionPolicy::reference()
        .with_weights(WeightPrecision::Exact(WeightFormat::Bf16));
    assert!(f32_engine.validate_policy(&pinned).is_err());
    bf16_engine.validate_policy(&pinned).unwrap();
    // Any-storage policies pass everywhere; decode sessions gate too.
    bf16_engine.validate_policy(&PrecisionPolicy::reference()).unwrap();
    assert!(f32_engine.decode_session(&pinned, 0).is_err());
    assert!(bf16_engine.decode_session(&pinned, 0).is_ok());
}
