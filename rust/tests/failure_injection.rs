//! Failure injection: corrupted artifacts, malformed configs and
//! out-of-contract requests must fail loudly with typed errors — never
//! panic, never return garbage.

use lamp::config::KvConfig;
use lamp::coordinator::{Engine, NativeEngine, PrecisionPolicy};
use lamp::model::{ModelConfig, Weights};
use lamp::runtime::{ArtifactStore, ModelExecutor};
use lamp::tensorio::{Tensor, TensorFile};
use lamp::util::Rng;
use std::path::PathBuf;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lamp_failinj_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn truncated_weight_file_rejected() {
    let dir = tmpdir("trunc");
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(1);
    let w = Weights::random(&cfg, &mut rng);
    let path = dir.join("weights_nano.lamp");
    w.to_tensor_file().unwrap().save(&path).unwrap();
    // Truncate the payload.
    let data = std::fs::read(&path).unwrap();
    std::fs::write(&path, &data[..data.len() - 100]).unwrap();
    assert!(Weights::load(&path, &cfg).is_err());
}

#[test]
fn bitflipped_magic_rejected() {
    let dir = tmpdir("magic");
    let path = dir.join("weights.lamp");
    let mut f = TensorFile::new();
    f.push(Tensor::f32("x", vec![2], &[1.0, 2.0]).unwrap()).unwrap();
    let mut data = f.to_bytes();
    data[0] ^= 0xFF;
    std::fs::write(&path, &data).unwrap();
    assert!(TensorFile::load(&path).is_err());
}

#[test]
fn meta_with_inconsistent_dims_rejected() {
    let kv = KvConfig::parse(
        "model.name = broken\nmodel.vocab = 64\nmodel.seq = 16\nmodel.layers = 2\n\
         model.heads = 3\nmodel.d_model = 32\nmodel.batch = 1\n",
    )
    .unwrap();
    // 32 % 3 != 0 → validation must fail.
    assert!(ModelConfig::from_kv(&kv).is_err());
}

#[test]
fn executor_rejects_garbage_hlo() {
    let dir = tmpdir("hlo");
    let hlo = dir.join("model_bad.hlo.txt");
    std::fs::write(&hlo, "this is not an HLO module").unwrap();
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(2);
    let w = Weights::random(&cfg, &mut rng);
    assert!(ModelExecutor::from_parts(cfg, &hlo, &w).is_err());
}

#[test]
fn store_reports_missing_artifacts() {
    let dir = tmpdir("empty");
    let store = ArtifactStore::open(&dir).unwrap();
    assert!(store.available_models().is_empty());
    assert!(store.model_config("xl").is_err());
    assert!(store.weights("xl").is_err());
}

#[test]
fn engine_rejects_out_of_contract_requests() {
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(3);
    let engine = NativeEngine::new(Weights::random(&cfg, &mut rng));
    // Token out of vocab.
    let r = engine.infer(&[vec![9999u32]], &PrecisionPolicy::reference(), 0);
    assert!(r.is_err());
    // Over-long sequence.
    let r = engine.infer(&[vec![0u32; 64]], &PrecisionPolicy::reference(), 0);
    assert!(r.is_err());
    // Invalid mu caught by policy validation.
    assert!(PrecisionPolicy::uniform(0).validate().is_err());
}

#[test]
fn weights_with_swapped_tensor_shape_rejected() {
    // Write a tensor file where one weight has transposed dims.
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(4);
    let w = Weights::random(&cfg, &mut rng);
    let good = w.to_tensor_file().unwrap();
    let mut bad = TensorFile::new();
    for t in good.tensors() {
        if t.name == "h0.attn.w_qkv" {
            let mut dims = t.dims.clone();
            dims.swap(0, 1);
            bad.push(Tensor { dims, ..t.clone() }).unwrap();
        } else {
            bad.push(t.clone()).unwrap();
        }
    }
    assert!(Weights::from_tensor_file(&bad, &cfg).is_err());
}
