//! Failure injection: corrupted artifacts, malformed configs and
//! out-of-contract requests must fail loudly with typed errors — never
//! panic, never return garbage.

use lamp::config::KvConfig;
use lamp::coordinator::{
    Engine, GenerateEvent, GenerateRequest, NativeEngine, PrecisionPolicy, Scheduler,
    SchedulerOptions,
};
use lamp::model::{Decode, ModelConfig, Weights};
use lamp::runtime::{ArtifactStore, ModelExecutor};
use lamp::tensorio::{Tensor, TensorFile};
use lamp::util::{Rng, ThreadPool};
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lamp_failinj_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn truncated_weight_file_rejected() {
    let dir = tmpdir("trunc");
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(1);
    let w = Weights::random(&cfg, &mut rng).unwrap();
    let path = dir.join("weights_nano.lamp");
    w.to_tensor_file().unwrap().save(&path).unwrap();
    // Truncate the payload.
    let data = std::fs::read(&path).unwrap();
    std::fs::write(&path, &data[..data.len() - 100]).unwrap();
    assert!(Weights::load(&path, &cfg).is_err());
}

#[test]
fn bitflipped_magic_rejected() {
    let dir = tmpdir("magic");
    let path = dir.join("weights.lamp");
    let mut f = TensorFile::new();
    f.push(Tensor::f32("x", vec![2], &[1.0, 2.0]).unwrap()).unwrap();
    let mut data = f.to_bytes();
    data[0] ^= 0xFF;
    std::fs::write(&path, &data).unwrap();
    assert!(TensorFile::load(&path).is_err());
}

#[test]
fn meta_with_inconsistent_dims_rejected() {
    let kv = KvConfig::parse(
        "model.name = broken\nmodel.vocab = 64\nmodel.seq = 16\nmodel.layers = 2\n\
         model.heads = 3\nmodel.d_model = 32\nmodel.batch = 1\n",
    )
    .unwrap();
    // 32 % 3 != 0 → validation must fail.
    assert!(ModelConfig::from_kv(&kv).is_err());
}

#[test]
fn executor_rejects_garbage_hlo() {
    let dir = tmpdir("hlo");
    let hlo = dir.join("model_bad.hlo.txt");
    std::fs::write(&hlo, "this is not an HLO module").unwrap();
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(2);
    let w = Weights::random(&cfg, &mut rng).unwrap();
    assert!(ModelExecutor::from_parts(cfg, &hlo, &w).is_err());
}

#[test]
fn store_reports_missing_artifacts() {
    let dir = tmpdir("empty");
    let store = ArtifactStore::open(&dir).unwrap();
    assert!(store.available_models().is_empty());
    assert!(store.model_config("xl").is_err());
    assert!(store.weights("xl").is_err());
}

#[test]
fn engine_rejects_out_of_contract_requests() {
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(3);
    let engine = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap());
    // Token out of vocab.
    let r = engine.infer(&[vec![9999u32]], &PrecisionPolicy::reference(), 0);
    assert!(r.is_err());
    // Over-long sequence.
    let r = engine.infer(&[vec![0u32; 64]], &PrecisionPolicy::reference(), 0);
    assert!(r.is_err());
    // Invalid mu caught by policy validation.
    assert!(PrecisionPolicy::uniform(0).validate().is_err());
}

#[test]
fn scheduler_failing_session_fails_only_its_request() {
    // A request whose decode_step errors mid-prefill (out-of-vocab token
    // injected past the Server's validation front door) must fail alone:
    // every other in-flight request completes with its solo-decode stream,
    // the slot is recycled, and nothing panics or deadlocks.
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(5);
    let engine = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap());
    let policy = PrecisionPolicy::lamp(3, 0.05, lamp::coordinator::Rule::Strict);

    // Solo oracle for the healthy requests.
    let healthy: Vec<(u64, Vec<u32>, usize)> = vec![
        (1, vec![1, 2, 3], 5),
        (2, vec![9, 8, 7, 6], 4),
        (3, vec![40, 41], 6),
    ];
    let mut solo = std::collections::HashMap::new();
    for (id, prompt, n) in &healthy {
        let (toks, _) = engine.generate(prompt, *n, &policy, Decode::Greedy, *id).unwrap();
        solo.insert(*id, toks);
    }

    // Two slots force the poisoned request to share the pool with healthy
    // traffic and force slot reuse after it dies.
    let opts = SchedulerOptions {
        max_sessions: 2,
        prefill_chunk: 2,
        pool: Some(Arc::new(ThreadPool::new(2))),
        ..Default::default()
    };
    let mut sched = Scheduler::new(&engine, opts);
    sched.admit(GenerateRequest::new(1, vec![1, 2, 3], 5, policy));
    sched.admit(GenerateRequest::new(9, vec![1, 9999, 2], 5, policy)); // poisoned
    sched.admit(GenerateRequest::new(2, vec![9, 8, 7, 6], 4, policy));
    sched.admit(GenerateRequest::new(3, vec![40, 41], 6, policy));

    let mut failed = Vec::new();
    let mut finished = Vec::new();
    for ev in sched.run() {
        match ev {
            GenerateEvent::Failed { id, error } => failed.push((id, error.to_string())),
            GenerateEvent::Finished(r) => finished.push(r),
            GenerateEvent::Token { .. } => {}
        }
    }
    assert_eq!(failed.len(), 1, "exactly the poisoned request fails: {failed:?}");
    assert_eq!(failed[0].0, 9);
    assert!(failed[0].1.contains("vocab"), "typed error surfaced: {}", failed[0].1);
    finished.sort_by_key(|r| r.id);
    assert_eq!(finished.len(), 3, "no lost responses");
    for r in &finished {
        assert_eq!(&r.tokens, &solo[&r.id], "healthy request {} perturbed", r.id);
    }
    let m = sched.metrics();
    assert_eq!((m.completed, m.failed), (3, 1));

    // The pool is not poisoned: the recycled slot serves new traffic and
    // still reproduces solo decode bit-for-bit.
    sched.admit(GenerateRequest::new(10, vec![5, 6], 4, policy));
    let responses = sched.run_to_completion().unwrap();
    assert_eq!(responses.len(), 1);
    let (want, _) = engine.generate(&[5, 6], 4, &policy, Decode::Greedy, 10).unwrap();
    assert_eq!(responses[0].tokens, want, "recycled slot leaked state");
}

#[test]
fn scheduler_all_sessions_failing_still_drains() {
    // Every request poisoned: the scheduler must retire them all as Failed
    // and end idle — no spinning, no slot leak.
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(6);
    let engine = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap());
    let policy = PrecisionPolicy::reference();
    let mut sched = Scheduler::new(
        &engine,
        SchedulerOptions { max_sessions: 2, prefill_chunk: 1, pool: None, ..Default::default() },
    );
    for id in 0..4u64 {
        sched.admit(GenerateRequest::new(id, vec![1, 9999], 3, policy));
    }
    let events = sched.run();
    let failures = events
        .iter()
        .filter(|e| matches!(e, GenerateEvent::Failed { .. }))
        .count();
    assert_eq!(failures, 4);
    assert!(sched.is_idle());
    assert_eq!(sched.metrics().failed, 4);
}

#[test]
fn weights_with_swapped_tensor_shape_rejected() {
    // Write a tensor file where one weight has transposed dims.
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(4);
    let w = Weights::random(&cfg, &mut rng).unwrap();
    let good = w.to_tensor_file().unwrap();
    let mut bad = TensorFile::new();
    for t in good.tensors() {
        if t.name == "h0.attn.w_qkv" {
            let mut dims = t.dims.clone();
            dims.swap(0, 1);
            bad.push(Tensor { dims, ..t.clone() }).unwrap();
        } else {
            bad.push(t.clone()).unwrap();
        }
    }
    assert!(Weights::from_tensor_file(&bad, &cfg).is_err());
}
