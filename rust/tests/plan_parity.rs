//! Whole-model PrecisionPlan parity — the PR-3 acceptance criteria.
//!
//! * An all-reference plan (and any attention-only plan) reproduces the
//!   pre-refactor FP32 path bit for bit, through both `forward_with` and
//!   `DecodeSession` decode. The pre-refactor path is replicated here from
//!   the public primitives it was built from (`matmul_bias_fast`,
//!   `causal_attention`, `layernorm`, GELU, `matmul_transposed_fast`).
//! * LAMP selection is demonstrably active at every composition site:
//!   per-site `LampStats` are non-zero under an active plan, and per-site
//!   repair beats uniform low precision at the same μ.
//! * Plans round-trip through `PrecisionPolicy::label`/`batch_compatible`
//!   and invalid plans are rejected with typed, site-naming errors.

use lamp::coordinator::{Engine, NativeEngine, PrecisionPolicy, Rule, SitePolicy};
use lamp::lamp::activation::Activation;
use lamp::lamp::softmax::SoftmaxRule;
use lamp::linalg::matmul::{matmul_bias_fast, matmul_transposed_fast};
use lamp::linalg::Matrix;
use lamp::model::attention::causal_attention;
use lamp::model::layernorm::{layernorm, LN_EPS};
use lamp::model::{
    forward, forward_with, AttentionPrecision, DecodeSession, ForwardScratch, ModelConfig,
    PrecisionPlan, Weights,
};
use lamp::util::Rng;

fn nano_weights(seed: u64) -> Weights {
    let mut rng = Rng::new(seed);
    Weights::random(&ModelConfig::nano(), &mut rng).unwrap()
}

fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
    a.shape() == b.shape()
        && a.data()
            .iter()
            .zip(b.data())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// The pre-refactor FP32 forward path, replicated from the public
/// primitives: vectorized FP32 matmuls everywhere over `Matrix`-typed
/// weights (the historical storage — `to_matrix()` on f32-storage
/// `WeightTensor`s reproduces exactly the old buffers), LAMP in attention
/// only. Valid for deterministic selection rules (the Random rule consumes
/// per-row streams whose derivation is engine-internal).
fn legacy_forward(w: &Weights, tokens: &[u32], prec: AttentionPrecision) -> Matrix {
    let cfg = &w.config;
    let d = cfg.d_model;
    let s = tokens.len();
    let wte = w.wte.to_matrix();
    let wpe = w.wpe.to_matrix();
    let mut x = Matrix::zeros(s, d);
    for (i, &t) in tokens.iter().enumerate() {
        let te = wte.row(t as usize);
        let pe = wpe.row(i);
        let xr = x.row_mut(i);
        for c in 0..d {
            xr[c] = te[c] + pe[c];
        }
    }
    for blk in &w.blocks {
        // Attention sublayer (pre-LN).
        let mut xn = x.clone();
        for i in 0..s {
            layernorm(xn.row_mut(i), &blk.ln1_g, &blk.ln1_b, LN_EPS);
        }
        let qkv = matmul_bias_fast(&xn, &blk.w_qkv.to_matrix(), &blk.b_qkv).unwrap();
        let mut q = Matrix::zeros(s, d);
        let mut k = Matrix::zeros(s, d);
        let mut v = Matrix::zeros(s, d);
        for i in 0..s {
            let row = qkv.row(i);
            q.row_mut(i).copy_from_slice(&row[..d]);
            k.row_mut(i).copy_from_slice(&row[d..2 * d]);
            v.row_mut(i).copy_from_slice(&row[2 * d..]);
        }
        let mut n = 0;
        let attn = causal_attention(&q, &k, &v, cfg.heads, prec, 0, &mut n);
        let proj = matmul_bias_fast(&attn, &blk.w_proj.to_matrix(), &blk.b_proj).unwrap();
        for i in 0..s {
            let pr = proj.row(i);
            let xr = x.row_mut(i);
            for c in 0..d {
                xr[c] += pr[c];
            }
        }
        // MLP sublayer (pre-LN), pure FP32.
        let mut xn = x.clone();
        for i in 0..s {
            layernorm(xn.row_mut(i), &blk.ln2_g, &blk.ln2_b, LN_EPS);
        }
        let mut hidden = matmul_bias_fast(&xn, &blk.w_fc.to_matrix(), &blk.b_fc).unwrap();
        for h in hidden.data_mut() {
            *h = Activation::Gelu.apply(*h);
        }
        let out = matmul_bias_fast(&hidden, &blk.w_out.to_matrix(), &blk.b_out).unwrap();
        for i in 0..s {
            let mr = out.row(i);
            let xr = x.row_mut(i);
            for c in 0..d {
                xr[c] += mr[c];
            }
        }
    }
    for i in 0..s {
        layernorm(x.row_mut(i), &w.lnf_g, &w.lnf_b, LN_EPS);
    }
    matmul_transposed_fast(&x, &wte).unwrap()
}

#[test]
fn attention_only_plans_reproduce_the_pre_refactor_path_bitwise() {
    // The headline bit-exactness criterion: a plan with every
    // non-attention site at reference is the pre-refactor engine.
    let w = nano_weights(1);
    let tokens: Vec<u32> = (0..20).map(|i| (i * 7 + 3) % 128).collect();
    for prec in [
        AttentionPrecision::reference(),
        AttentionPrecision::uniform(3),
        AttentionPrecision::lamp(3, 0.02, SoftmaxRule::Strict),
        AttentionPrecision::lamp(3, 0.1, SoftmaxRule::Relaxed),
    ] {
        let legacy = legacy_forward(&w, &tokens, prec);
        // Through forward (attention-only plan via the From shim) ...
        let plan: PrecisionPlan = prec.into();
        assert!(plan.is_attention_only());
        let got = forward(&w, &tokens, plan, 9).unwrap();
        assert!(
            bits_equal(&legacy, &got.logits),
            "plan forward diverged from the pre-refactor path under {prec:?}"
        );
        // ... through forward_with with scratch reuse ...
        let mut scratch = ForwardScratch::for_config(&w.config);
        let reused = forward_with(&w, &tokens, plan, 9, &mut scratch, None).unwrap();
        assert!(bits_equal(&legacy, &reused.logits));
        // ... and through KV-cache decode: the last decoded position's
        // logits equal the last legacy row.
        let mut session = DecodeSession::new(&w, plan, 9);
        session.prefill(&tokens).unwrap();
        let last = legacy.row(tokens.len() - 1);
        for (c, (a, b)) in session.logits().iter().zip(last).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "decode col {c} under {prec:?}");
        }
        // Non-attention sites recompute nothing on attention-only plans.
        assert_eq!(got.stats.mlp.recomputed, 0);
        assert_eq!(got.stats.norm.recomputed, 0);
        assert_eq!(got.stats.sampler.recomputed, 0);
    }
}

#[test]
fn f32_storage_round_trip_and_quantized_storage_still_short_circuit() {
    // PR-4 acceptance, pinned: (1) `quantize_to(F32)` is the identity on
    // the serving path — same logits bit for bit as the original weights
    // (which themselves equal the pre-refactor engine, see above); (2) on
    // *quantized* storage, the attention-only plan still equals the legacy
    // replica evaluated on the dequantized weights — the fused kernels add
    // no error beyond the one-time storage quantization.
    use lamp::linalg::WeightFormat;
    let w = nano_weights(6);
    let tokens: Vec<u32> = (0..18).map(|i| (i * 5 + 2) % 128).collect();
    let prec = AttentionPrecision::lamp(3, 0.05, SoftmaxRule::Strict);
    let roundtrip = w.quantize_to(WeightFormat::F32).unwrap();
    let a = forward(&w, &tokens, prec, 3).unwrap();
    let b = forward(&roundtrip, &tokens, prec, 3).unwrap();
    assert!(bits_equal(&a.logits, &b.logits), "F32 round trip changed logits");
    for fmt in [WeightFormat::Bf16, WeightFormat::PsRounded { mu: 7 }] {
        let q = w.quantize_to(fmt).unwrap();
        let legacy = legacy_forward(&q, &tokens, prec);
        let got = forward(&q, &tokens, prec, 9).unwrap();
        assert!(
            bits_equal(&legacy, &got.logits),
            "{fmt:?}: fused storage path diverged from legacy-on-dequantized"
        );
        // Storage error is real: quantized logits differ from f32 ones.
        assert!(
            !bits_equal(&a.logits, &got.logits),
            "{fmt:?}: quantization left every logit bit-identical"
        );
    }
}

#[test]
fn plan_sweep_activates_every_site_with_nonzero_stats() {
    let w = nano_weights(2);
    let tokens: Vec<u32> = (0..16).map(|i| (i * 11 + 5) % 128).collect();
    let plan = PrecisionPlan::attention_only(AttentionPrecision::lamp(
        3,
        0.02,
        SoftmaxRule::Strict,
    ))
    .with_mlp(AttentionPrecision::lamp(3, 0.5, SoftmaxRule::Strict))
    .with_norm(AttentionPrecision::lamp(3, 0.5, SoftmaxRule::Strict))
    .with_sampler(AttentionPrecision::lamp(3, 0.0, SoftmaxRule::Strict));
    let out = forward(&w, &tokens, plan, 3).unwrap();
    assert!(out.stats.recomputed > 0, "attention site inactive");
    assert!(out.stats.mlp.recomputed > 0, "mlp site inactive");
    assert!(out.stats.norm.recomputed > 0, "norm site inactive");
    assert!(out.stats.sampler.recomputed > 0, "sampler site inactive");
    // Decode accounts the identical per-site counters.
    let mut session = DecodeSession::new(&w, plan, 3);
    session.prefill(&tokens).unwrap();
    assert_eq!(session.stats().mlp, out.stats.mlp);
    assert_eq!(session.stats().norm, out.stats.norm);
    assert_eq!(session.stats().sampler, out.stats.sampler);
    assert_eq!(session.stats().recomputed, out.stats.recomputed);
}

#[test]
fn per_site_repair_beats_uniform_low_precision() {
    // For each non-attention site: LAMP repair at μ strictly reduces the
    // deviation from the FP32 reference vs uniform PS(μ) at that site.
    let w = nano_weights(3);
    let tokens: Vec<u32> = (0..16).map(|i| (i * 13 + 1) % 128).collect();
    let reference = forward(&w, &tokens, PrecisionPlan::reference(), 0).unwrap();
    let err = |plan: PrecisionPlan| -> f32 {
        forward(&w, &tokens, plan, 0)
            .unwrap()
            .logits
            .max_abs_diff(&reference.logits)
            .unwrap()
    };
    let base = PrecisionPlan::reference();
    // MLP site.
    let e_uni = err(base.with_mlp(AttentionPrecision::uniform(2)));
    let e_lamp = err(base.with_mlp(AttentionPrecision::lamp(2, 0.0, SoftmaxRule::Strict)));
    assert!(e_uni > 0.0, "uniform PS(2) mlp must perturb logits");
    assert!(e_lamp < e_uni, "mlp repair: lamp={e_lamp} uniform={e_uni}");
    // Norm site.
    let e_uni = err(base.with_norm(AttentionPrecision::uniform(2)));
    let e_lamp = err(base.with_norm(AttentionPrecision::lamp(2, 0.1, SoftmaxRule::Strict)));
    assert!(e_uni > 0.0, "uniform PS(2) norm must perturb logits");
    assert!(e_lamp < e_uni, "norm repair: lamp={e_lamp} uniform={e_uni}");
    // Sampler site.
    let e_uni = err(base.with_sampler(AttentionPrecision::uniform(2)));
    let e_lamp =
        err(base.with_sampler(AttentionPrecision::lamp(2, 0.0, SoftmaxRule::Strict)));
    assert!(e_uni > 0.0, "uniform PS(2) sampler must perturb logits");
    assert!(e_lamp < e_uni, "sampler repair: lamp={e_lamp} uniform={e_uni}");
}

#[test]
fn tightening_tau_never_increases_per_site_unrepaired_sensitivity() {
    // Model-level companion of the selector-level monotonicity property
    // tests: tightening one site's τ (all else fixed) never decreases the
    // number of repaired outputs at that site on the same inputs' first
    // forward, and the end-to-end deviation from reference shrinks or
    // stays equal in the expected direction for the directly-repaired
    // site outputs. We assert the recompute-count monotonicity, which is
    // exact for the closed-form threshold selections at fixed inputs.
    let w = nano_weights(4);
    let tokens: Vec<u32> = (0..12).map(|i| (i * 5 + 2) % 128).collect();
    // Single-layer-deep check: only the sampler site is active, so the
    // logits-site inputs are identical across τ values and thresholding
    // monotonicity applies exactly.
    let taus = [0.5f32, 0.2, 0.1, 0.05, 0.0];
    let mut last = 0usize;
    for (i, &tau) in taus.iter().enumerate() {
        let plan = PrecisionPlan::reference()
            .with_sampler(AttentionPrecision::lamp(3, tau, SoftmaxRule::Strict));
        let out = forward(&w, &tokens, plan, 0).unwrap();
        if i > 0 {
            assert!(
                out.stats.sampler.recomputed >= last,
                "tightening tau reduced sampler repairs: {} < {last} at tau={tau}",
                out.stats.sampler.recomputed
            );
        }
        last = out.stats.sampler.recomputed;
    }
    // Same for the norm site (inputs to the final norm are τ-independent
    // when only the norm site is active).
    let mut last = 0usize;
    for (i, &tau) in [1.5f32, 1.0, 0.5, 0.1].iter().enumerate() {
        let plan = PrecisionPlan::reference()
            .with_norm(AttentionPrecision::lamp(3, tau, SoftmaxRule::Strict));
        let out = forward(&w, &tokens, plan, 0).unwrap();
        if i > 0 {
            assert!(
                out.stats.norm.recomputed >= last,
                "tightening tau reduced norm repairs at tau={tau}"
            );
        }
        last = out.stats.norm.recomputed;
    }
}

#[test]
fn policies_round_trip_through_label_and_batching() {
    // Distinct per-site policies get distinct labels; equal ones batch.
    let a = PrecisionPolicy::lamp(4, 0.1, Rule::Strict)
        .with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict));
    let b = PrecisionPolicy::lamp(4, 0.1, Rule::Strict)
        .with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict));
    let c = PrecisionPolicy::lamp(4, 0.1, Rule::Strict)
        .with_norm(SitePolicy::lamp(7, 0.5, Rule::Strict));
    assert_eq!(a.label(), b.label());
    assert!(a.batch_compatible(&b));
    assert_ne!(a.label(), c.label());
    assert!(!a.batch_compatible(&c));
    // The engine translation preserves every site.
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(5);
    let engine = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap());
    let plan = engine.decode_precision(&a);
    assert_eq!(plan.mlp.mu, 7);
    assert!(plan.norm.is_reference());
}

#[test]
fn invalid_plans_rejected_with_typed_site_errors() {
    for (policy, site) in [
        (
            PrecisionPolicy::reference().with_mlp(SitePolicy::lamp(0, 0.1, Rule::Strict)),
            "mlp",
        ),
        (
            PrecisionPolicy::reference()
                .with_norm(SitePolicy::lamp(4, f32::NAN, Rule::Strict)),
            "norm",
        ),
        (
            PrecisionPolicy::reference()
                .with_sampler(SitePolicy::lamp(4, -1.0, Rule::Strict)),
            "sampler",
        ),
    ] {
        let err = policy.validate().unwrap_err().to_string();
        assert!(err.contains(site), "error must name the site: {err}");
    }
    // And the engine-level plan validation agrees.
    let bad = PrecisionPlan::reference().with_mlp(AttentionPrecision {
        mu: 42,
        tau: 0.1,
        rule: SoftmaxRule::Strict,
    });
    assert!(bad.validate().is_err());
}
