//! Smoke-level runs of every experiment driver at tiny scale: each figure
//! and table must execute end-to-end and produce sane series.

use lamp::experiments::{self, EvalOptions};

fn tiny_opts() -> EvalOptions {
    EvalOptions {
        num_seqs: 2,
        seq_len: 10,
        stream_seed: 3,
        workers: 2,
        // Use trained artifacts when available, random weights otherwise —
        // both paths must work.
        artifacts: Some(lamp::runtime::ArtifactStore::default_dir()
            .to_string_lossy()
            .to_string()),
        quick: true,
    }
}

#[test]
fn all_experiments_run_at_tiny_scale() {
    for name in experiments::all_names() {
        // table1/figs over xl are heavier; tiny opts keep this bounded.
        let tables = experiments::run(name, &tiny_opts())
            .unwrap_or_else(|e| panic!("{name} failed: {e}"));
        assert!(!tables.is_empty(), "{name} produced no tables");
        for t in &tables {
            assert!(!t.rows.is_empty(), "{name} produced an empty table");
            let rendered = t.render();
            assert!(rendered.contains("##"), "{name} render broken");
        }
    }
}

#[test]
fn unknown_experiment_rejected() {
    assert!(experiments::run("fig99", &tiny_opts()).is_err());
}

#[test]
fn fig7_lamp_dominates_random() {
    // The crux claim (App. C.4): at equal budget, LAMP ≪ random. Verify on
    // the tiny panel by comparing KL at the sharpest τ in the fig7 table.
    use lamp::coordinator::{PrecisionPolicy, Rule};
    use lamp::data::Domain;
    use lamp::experiments::common::{load_weights, EvalPanel};
    let opts = EvalOptions { num_seqs: 3, seq_len: 16, ..tiny_opts() };
    let weights = load_weights("xl", &opts).unwrap();
    let panel = EvalPanel::build(weights, Domain::Web, &opts).unwrap();
    let lamp = panel
        .evaluate(&PrecisionPolicy::lamp(4, 0.02, Rule::Strict), 0)
        .unwrap();
    let rand = panel
        .evaluate(&PrecisionPolicy::lamp(4, 0.02, Rule::Random), 0)
        .unwrap();
    if lamp.recomputed > 10 {
        assert!(
            lamp.kl < rand.kl,
            "adaptive selection must beat random: lamp={} random={}",
            lamp.kl,
            rand.kl
        );
    }
}
