//! Observability inertness pins: every per-request token stream, every
//! `LampStats` count, and every trials canonical artifact must be
//! **bit-identical** with the obs plane attached or absent — across
//! plain decode, speculative decode, preemption, and chaos fault
//! injection — and the virtual-clock trace/metrics exports themselves
//! must be deterministic across reruns.

use lamp::coordinator::{
    replay, FaultInjector, FaultPlan, KvCacheOptions, NativeEngine, PrecisionPolicy,
    ReplayOptions, ReplayReport, Rule, SchedulerOptions, SitePolicy, SpecPolicy, WeightFormat,
};
use lamp::data::{TraceKind, TraceSpec};
use lamp::model::{ModelConfig, Weights};
use lamp::obs::{trace, ObsHub, SpanKind};
use lamp::util::Rng;
use std::sync::Arc;

fn nano_engine(seed: u64) -> NativeEngine {
    let mut rng = Rng::new(seed);
    NativeEngine::new(Weights::random(&ModelConfig::nano(), &mut rng).unwrap())
}

fn trace_spec(kind: TraceKind, requests: usize, new_tokens: usize) -> Vec<lamp::data::TraceRequest> {
    let cfg = ModelConfig::nano();
    let mut s = TraceSpec::new(kind, cfg.vocab, cfg.seq);
    s.requests = requests;
    s.new_tokens = new_tokens;
    s.generate().unwrap()
}

fn traced_hub(capacity: usize) -> Arc<ObsHub> {
    Arc::new(ObsHub::new().with_virtual_clock().with_tracer(capacity))
}

/// The inertness oracle: identical outputs whether or not a hub (with a
/// tracer) is attached. Returns both reports plus the attached hub.
fn replay_on_and_off(
    engine: &dyn lamp::coordinator::Engine,
    trace: &[lamp::data::TraceRequest],
    base: &ReplayOptions,
) -> (ReplayReport, ReplayReport, Arc<ObsHub>) {
    let off = replay(engine, trace, base).unwrap();
    let hub = traced_hub(1 << 16);
    let mut on_opts = base.clone();
    on_opts.scheduler.obs = Some(Arc::clone(&hub));
    let on = replay(engine, trace, &on_opts).unwrap();
    (off, on, hub)
}

fn assert_reports_identical(off: &ReplayReport, on: &ReplayReport, what: &str) {
    assert_eq!(off.steps, on.steps, "{what}: iteration count changed");
    assert_eq!(off.responses.len(), on.responses.len(), "{what}: response count");
    for (a, b) in off.responses.iter().zip(&on.responses) {
        assert_eq!(a.id, b.id, "{what}: response order");
        assert_eq!(a.tokens, b.tokens, "{what}: id {} stream changed", a.id);
        assert_eq!(
            a.stats.recomputed, b.stats.recomputed,
            "{what}: id {} recompute accounting changed",
            a.id
        );
        assert_eq!(
            a.stats.causal_total, b.stats.causal_total,
            "{what}: id {} causal accounting changed",
            a.id
        );
        assert_eq!(
            a.stats.spec.rounds, b.stats.spec.rounds,
            "{what}: id {} spec accounting changed",
            a.id
        );
    }
    let off_failures: Vec<_> = off.failures.iter().map(|(id, _)| *id).collect();
    let on_failures: Vec<_> = on.failures.iter().map(|(id, _)| *id).collect();
    assert_eq!(off_failures, on_failures, "{what}: failure set changed");
    assert_eq!(
        off.metrics.generated_tokens, on.metrics.generated_tokens,
        "{what}: token accounting changed"
    );
    assert_eq!(
        off.metrics.preemptions, on.metrics.preemptions,
        "{what}: preemption schedule changed"
    );
    assert_eq!(off.metrics.retries, on.metrics.retries, "{what}: retry schedule changed");
}

#[test]
fn plain_decode_replay_is_inert_and_single_counted() {
    let engine = nano_engine(11);
    let trace = trace_spec(TraceKind::Bursty, 6, 5);
    let opts = ReplayOptions::new(PrecisionPolicy::lamp(3, 0.05, Rule::Strict));
    let (off, on, hub) = replay_on_and_off(&engine, &trace, &opts);
    assert_reports_identical(&off, &on, "plain decode");
    assert!(off.failures.is_empty());

    // LampStats are single-counted: the registry's fold over retired
    // requests equals the per-response sums exactly.
    let snap = hub.registry().snapshot();
    let recomputed: u64 = on.responses.iter().map(|r| r.stats.recomputed as u64).sum();
    let causal: u64 = on.responses.iter().map(|r| r.stats.causal_total as u64).sum();
    let generated: u64 = on.responses.iter().map(|r| r.generated().len() as u64).sum();
    assert_eq!(snap.counter("lamp.attention.recomputed"), Some(recomputed));
    assert_eq!(snap.counter("lamp.attention.total"), Some(causal));
    assert_eq!(snap.counter("sched.generated_tokens"), Some(generated));
    assert_eq!(snap.counter("sched.completed"), Some(on.responses.len() as u64));
    assert_eq!(snap.counter("sched.failed"), Some(0));
    // The steps counter counts productive iterations only (all-backoff
    // iterations return early), so it is bounded by the driver's count.
    let steps = snap.counter("sched.steps").unwrap();
    assert!(steps > 0 && steps <= on.steps as u64);

    // The trace recorded the full lifecycle, with virtual-tick stamps.
    let tracer = hub.tracer().unwrap();
    let spans = tracer.events();
    assert!(!spans.is_empty());
    for kind in [SpanKind::Enqueue, SpanKind::Admit, SpanKind::Prefill, SpanKind::Decode] {
        assert!(
            spans.iter().any(|s| s.kind == kind),
            "no {} span recorded",
            kind.as_str()
        );
    }
    let retired = spans.iter().filter(|s| s.kind == SpanKind::Retire).count();
    assert_eq!(retired, trace.len(), "one retire span per request");
    // Virtual ticks are bounded by the arrival span plus the iteration
    // count (the clock jumps idle gaps); wall nanoseconds would be far
    // larger.
    let max_tick = spans.iter().map(|s| s.end).max().unwrap();
    let last_arrival = trace.iter().map(|r| r.arrival_step as u64).max().unwrap_or(0);
    assert!(
        max_tick <= last_arrival + on.steps as u64,
        "span stamps must be virtual ticks, not wall ns (max {max_tick})"
    );
}

#[test]
fn speculative_replay_is_inert() {
    let engine = nano_engine(5);
    let trace = trace_spec(TraceKind::ZipfMix, 5, 8);
    let policy = PrecisionPolicy::lamp(3, 0.1, Rule::Strict)
        .with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(2), 3)));
    let opts = ReplayOptions::new(policy);
    let (off, on, hub) = replay_on_and_off(&engine, &trace, &opts);
    assert_reports_identical(&off, &on, "speculative decode");

    let rounds: u64 = on.responses.iter().map(|r| r.stats.spec.rounds as u64).sum();
    assert!(rounds > 0, "spec policy must actually speculate");
    let snap = hub.registry().snapshot();
    assert_eq!(snap.counter("spec.rounds"), Some(rounds));
    let drafted: u64 = on.responses.iter().map(|r| r.stats.spec.drafted as u64).sum();
    let accepted: u64 = on.responses.iter().map(|r| r.stats.spec.accepted as u64).sum();
    assert_eq!(snap.counter("spec.drafted"), Some(drafted));
    assert_eq!(snap.counter("spec.accepted"), Some(accepted));
    // Every speculation round lands in exactly one acceptance bucket.
    let hist = snap.hist("spec.accept_len").expect("acceptance histogram published");
    assert_eq!(hist.counts.iter().sum::<u64>(), rounds);

    // Draft and verify units both show up as spans.
    let spans = hub.tracer().unwrap().events();
    assert!(spans.iter().any(|s| s.kind == SpanKind::Draft), "no draft span");
    assert!(spans.iter().any(|s| s.kind == SpanKind::Verify), "no verify span");
}

#[test]
fn preemption_replay_is_inert() {
    // A deliberately starved KV pool forces preempt/resume churn; the
    // schedule and streams must not move when the obs plane attaches.
    let cfg = ModelConfig::nano();
    let mut wrng = Rng::new(23);
    let w = Weights::random(&cfg, &mut wrng).unwrap();
    let mut kv = KvCacheOptions::serving(&cfg, WeightFormat::F32, 1);
    kv.block_size = 4;
    kv.capacity_blocks = 12;
    kv.sharing = false;
    let engine = NativeEngine::new(w).with_kv_cache(kv).unwrap();

    // Hand-built trace sized so two concurrent 31-token sessions overflow
    // the 48-token-slot pool (the proven-preemption configuration of
    // scheduler_parity's fault test).
    let trace: Vec<lamp::data::TraceRequest> = (0..3u64)
        .map(|id| lamp::data::TraceRequest {
            arrival_step: 0,
            prompt: vec![(id as u32 * 11 + 3) % 128, 7, 9, 2],
            new_tokens: 27,
            seed: id,
            decode: lamp::model::Decode::Greedy,
        })
        .collect();
    let mut opts = ReplayOptions::new(PrecisionPolicy::lamp(3, 0.05, Rule::Strict));
    opts.scheduler.max_sessions = 2;
    opts.scheduler.prefill_chunk = 4;
    let (off, on, hub) = replay_on_and_off(&engine, &trace, &opts);
    assert_reports_identical(&off, &on, "preemption");
    assert!(on.metrics.preemptions > 0, "the starved pool must force preemption");

    let spans = hub.tracer().unwrap().events();
    let preempts = spans.iter().filter(|s| s.kind == SpanKind::Preempt).count();
    let resumes = spans.iter().filter(|s| s.kind == SpanKind::Resume).count();
    assert_eq!(preempts, on.metrics.preemptions, "one preempt span per preemption");
    assert_eq!(preempts, resumes, "every preempted request resumed");
}

#[test]
fn chaos_replays_are_inert_across_seeds() {
    // Chaos plans inject transient faults and fatal ones; under the
    // virtual clock the retry schedule is iteration-counted, so outcomes
    // (including which requests fail) must be identical obs-on/off.
    for seed in [0xC4A05u64, 7, 99] {
        let engine = nano_engine(31);
        let inj = FaultInjector::new(engine, FaultPlan::chaos(seed)).unwrap();
        let trace = trace_spec(TraceKind::ZipfMix, 5, 6);
        let opts = ReplayOptions::new(PrecisionPolicy::lamp(3, 0.05, Rule::Strict));
        let (off, on, hub) = replay_on_and_off(&inj, &trace, &opts);
        assert_reports_identical(&off, &on, &format!("chaos seed {seed:#x}"));

        // Failed requests close with a fail span, retired ones with retire.
        let spans = hub.tracer().unwrap().events();
        let fails = spans.iter().filter(|s| s.kind == SpanKind::Fail).count();
        let retires = spans.iter().filter(|s| s.kind == SpanKind::Retire).count();
        assert_eq!(fails, on.failures.len(), "seed {seed:#x}: fail span accounting");
        assert_eq!(retires, on.responses.len(), "seed {seed:#x}: retire span accounting");
    }
}

#[test]
fn trace_and_metrics_exports_are_deterministic_across_reruns() {
    let engine = nano_engine(13);
    let trace = trace_spec(TraceKind::Bursty, 5, 6);
    let opts = ReplayOptions::new(PrecisionPolicy::lamp(3, 0.08, Rule::Relaxed));

    let mut jsonls = Vec::new();
    let mut metrics = Vec::new();
    for _ in 0..2 {
        let hub = traced_hub(1 << 16);
        let mut run_opts = opts.clone();
        run_opts.scheduler.obs = Some(Arc::clone(&hub));
        replay(&engine, &trace, &run_opts).unwrap();
        jsonls.push(trace::to_jsonl(&hub.tracer().unwrap().events()));
        metrics.push(hub.registry().snapshot().to_json());
    }
    assert_eq!(jsonls[0], jsonls[1], "span trace must be byte-identical across reruns");
    assert_eq!(metrics[0], metrics[1], "metrics snapshot must be byte-identical");

    // The JSONL round-trips through the parser the `lamp obs` CLI uses,
    // and the snapshot round-trips through its JSON codec.
    let events = trace::parse_jsonl(&jsonls[0]);
    assert_eq!(trace::to_jsonl(&events), jsonls[0]);
    let snap = lamp::obs::Snapshot::from_json(&metrics[0]).unwrap();
    assert_eq!(snap.to_json(), metrics[0]);
    assert!(!snap.to_prometheus().is_empty());
    let chrome = trace::to_chrome(&events);
    assert!(chrome.starts_with("[\n") && chrome.trim_end().ends_with(']'));
}

#[test]
fn trials_canonical_artifacts_are_byte_identical_with_obs() {
    // The full trials stack: `run` (no hub) versus `run_with_obs` with a
    // traced virtual hub must emit byte-identical canonical artifacts —
    // including the chaos trial, whose fault verdicts ride the same
    // virtual retry schedule.
    for name in ["bursty", "chaos-replay"] {
        let Some(text) = lamp::trials::builtin(name) else {
            panic!("builtin trial {name} missing");
        };
        let manifest = lamp::trials::TrialManifest::parse(text).unwrap();
        let off = lamp::trials::run(&manifest).unwrap();
        let hub = traced_hub(1 << 16);
        let on = lamp::trials::run_with_obs(&manifest, Some(Arc::clone(&hub))).unwrap();
        assert_eq!(
            off.canonical, on.canonical,
            "trial {name}: observability leaked into the canonical artifact"
        );
        assert!(!hub.tracer().unwrap().is_empty(), "trial {name}: no spans recorded");

        // And the rider exports are themselves rerun-deterministic.
        let hub2 = traced_hub(1 << 16);
        lamp::trials::run_with_obs(&manifest, Some(Arc::clone(&hub2))).unwrap();
        assert_eq!(
            trace::to_jsonl(&hub.tracer().unwrap().events()),
            trace::to_jsonl(&hub2.tracer().unwrap().events()),
            "trial {name}: trace export diverged across reruns"
        );
    }
}
